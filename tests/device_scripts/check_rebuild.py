"""Elastic rebuild acceptance (12 CPU devices): detect → degrade →
rebuild → resume.

Part 1 — communicator level: a fault injector kills a device subset
mid-run on a (3,4) torus; the watchdog policy classifies the loss, the
survivors are re-factorized into a (2,4) torus via ``TorusComm.rebuild``,
and the resumed all-to-all on the survivor torus is bit-exact (factorized
vs direct vs the transpose oracle).  Exactly the dead comm's plan-LRU
slice is invalidated — a co-resident comm keeps its cached plans — and
tuning-DB winners whose per-axis extents survived migrate to the new
device fingerprint.

Part 2 — trainer level: training on a (6,2) mesh checkpoints at step 5,
loses 4 devices at step 8, recovers through the escalation policy
(rebuild onto the (4,2) survivor mesh + elastic restore), and finishes at
step 10 with global params identical to a reference run that restores the
same checkpoint onto the survivor mesh directly.

Exits nonzero on any failure.
"""

import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.core.autotune import TuningDB, plan_db_key
from repro.core.cache import cart_create
from repro.core.comm import free_comms, torus_comm
from repro.core.faults import DeviceLossError, FaultInjector, FaultSpec
from repro.core.plan import free_plans, plan_cache_stats
from repro.data import CopyTaskConfig, SyntheticLM
from repro.models import ModelConfig, build_model, make_train_step
from repro.models.common import param_shardings
from repro.optim import AdamW, AdamWConfig
from repro.parallel.sharding import ShardingRules
from repro.runtime.trainer import Trainer, TrainerConfig
from repro.runtime.watchdog import StragglerWatchdog


def check_comm_rebuild(tmp):
    db = TuningDB(Path(tmp) / "tuning.json")
    mesh = cart_create(12, (3, 4), ("i", "j"))
    comm = torus_comm(mesh, ("i", "j"), db=db)
    plan = comm.all_to_all((4,), jnp.float32, backend="factorized")
    other = torus_comm((5,), ("k",))
    kept = other.all_to_all((4,), jnp.float32, backend="direct")
    plans_before = plan_cache_stats()["size"]

    # a measured winner on the old fingerprint, over axis j (extent 4 —
    # which survives the re-factorization below)
    db.put(plan_db_key(comm.dev_key, (4,), ("j",), (8,), "float32",
                       "natural"),
           {"version": 1,
            "winner": {"backend": "factorized", "round_order": [0],
                       "n_chunks": 1, "median_us": 10.0},
            "axis_names": ["j"], "dims": [4]})

    # inject: devices 8..11 die on the 3rd collective round
    inj = FaultInjector((FaultSpec("device_loss", at_call=3,
                                   devices=(8, 9, 10, 11)),))
    inj.install(plan)
    x = (jnp.arange(12 * 12 * 4) % 251).reshape(12, 12, 4) \
        .astype(jnp.float32)
    err = None
    for _ in range(3):
        try:
            plan.host_fn()(x)
        except DeviceLossError as e:
            err = e
            break
    assert err is not None and err.devices == (8, 9, 10, 11)

    # detect: the watchdog policy turns the loss into a recover action
    action = StragglerWatchdog().policy(3, 0.0, verdict="device_loss")
    assert action.kind == "recover", action

    # rebuild on the survivors: p'=8, d=2 -> (2,4) torus, same axes
    survivors = [dv for dv in mesh.devices.flat
                 if dv.id not in err.devices]
    fresh = comm.rebuild(survivors)
    assert fresh.p == 8 and fresh.dims == (2, 4)
    assert fresh.axis_names == ("i", "j") and fresh.mesh is not None
    assert comm._freed
    assert fresh.rebuilt_from == {"dims": [3, 4], "axes": ["i", "j"],
                                  "p": 12}

    # exactly the dead comm's plan slice is gone; the co-resident comm's
    # plan survived as the identical cached object
    assert plan_cache_stats()["size"] == plans_before - 1
    assert other.all_to_all((4,), jnp.float32, backend="direct") is kept

    # tuning winner migrated: axis j kept extent 4 across the rebuild
    assert fresh.tuning_migrated == 1, fresh.tuning_migrated
    rec = db.get(plan_db_key(fresh.dev_key, (4,), ("j",), (8,),
                             "float32", "natural"))
    assert rec is not None and rec["migrated"] is True

    # resume: the re-resolved all-to-all on the survivor torus is
    # bit-exact (factorized vs direct vs the transpose oracle)
    x8 = (jnp.arange(8 * 8 * 4) % 251).reshape(8, 8, 4) \
        .astype(jnp.float32)
    yf = np.array(fresh.all_to_all((4,), jnp.float32,
                                   backend="factorized").host_fn()(x8))
    yd = np.array(fresh.all_to_all((4,), jnp.float32,
                                   backend="direct").host_fn()(x8))
    np.testing.assert_array_equal(yf, yd)
    np.testing.assert_array_equal(yf, np.array(x8).transpose(1, 0, 2))
    print("OK rebuild: (3,4) -> (2,4) survivor torus, bit-exact "
          "all-to-all, plan slice invalidated, 1 tuning record migrated")


def _setup(mesh):
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      param_dtype="float32", compute_dtype="float32",
                      remat=False)
    rules = ShardingRules()
    model = build_model(cfg)
    opt = AdamW(AdamWConfig(lr=1e-3, weight_decay=0.0))
    sh = param_shardings(model.specs(), mesh, rules)
    step = jax.jit(make_train_step(model, opt, mesh, rules))
    return model, opt, sh, step


def _data(mesh, state=None):
    d = SyntheticLM(CopyTaskConfig(vocab=64, seq_len=16,
                                   global_batch=12), mesh=mesh,
                    task="copy")
    if state is not None:
        d.load_state_dict(state)
    return d


def check_trainer_elastic(tmp):
    devices = jax.devices()
    mesh_a = Mesh(np.array(devices[:12]).reshape(6, 2),
                  ("data", "model"))
    survivors = [dv for dv in devices if dv.id < 8]
    mesh_b = Mesh(np.array(survivors).reshape(4, 2), ("data", "model"))

    def shardings_for(sh):
        return {"params": sh,
                "opt_state": {"mu": sh, "nu": sh,
                              "step": NamedSharding(mesh_b, P())}}

    model, opt, sh_a, step_a = _setup(mesh_a)
    params = jax.jit(model.init,
                     out_shardings=sh_a)(jax.random.PRNGKey(0))
    opt_state = opt.init(params)

    # devices 8..11 die on the 8th train step (after the step-5 save)
    inj = FaultInjector((FaultSpec("device_loss", at_call=8,
                                   devices=(8, 9, 10, 11)),))

    def rebuild_fn(trainer, err):
        assert isinstance(err, DeviceLossError)
        _, _, sh_b, step_b = _setup(mesh_b)
        trainer.train_step = step_b        # unwrapped: survivors only
        trainer.data = _data(mesh_b)
        return shardings_for(sh_b)

    trainer = Trainer(
        config=TrainerConfig(total_steps=10, checkpoint_dir=tmp,
                             checkpoint_every=5, log_every=5,
                             async_checkpoint=False, elastic=True),
        train_step=inj.wrap(step_a, "train_step"),
        data=_data(mesh_a), params=params, opt_state=opt_state,
        watchdog=StragglerWatchdog(slow_factor=50.0, hang_factor=1e4,
                                   hang_floor_seconds=120.0),
        rebuild_fn=rebuild_fn)
    assert trainer.run() == "done"
    assert trainer.step == 10
    assert trainer.recoveries_done == 1
    assert inj.fired == [("device_loss", "train_step", 8)]
    kinds = [e[0] for e in trainer.watchdog.events]
    assert "device_loss" in kinds and "action:recover" in kinds

    # reference: restore the same step-5 checkpoint onto the survivor
    # mesh directly and run the same 5 steps — identical global params
    _, _, sh_b, step_b = _setup(mesh_b)
    target = {"params": params, "opt_state": opt_state}
    tree, extra, _ = CheckpointManager(tmp).restore(
        target, shardings_for(sh_b), step=5)
    p_ref, o_ref = tree["params"], tree["opt_state"]
    data_ref = _data(mesh_b, extra["data"])
    for _ in range(5):
        p_ref, o_ref, _ = step_b(p_ref, o_ref, data_ref.next())

    for a, b in zip(jax.tree.leaves(trainer.params),
                    jax.tree.leaves(p_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("OK elastic trainer: device loss at step 8, recovered onto "
          "(4,2) survivor mesh, resumed to step 10 with params "
          "identical to the direct-restore reference")


def main():
    assert jax.device_count() >= 12, \
        f"need 12 devices, got {jax.device_count()}"
    free_plans()
    free_comms()
    with tempfile.TemporaryDirectory() as tmp1:
        check_comm_rebuild(tmp1)
    with tempfile.TemporaryDirectory() as tmp2:
        check_trainer_elastic(tmp2)
    print("OK rebuild: detect -> degrade -> rebuild -> resume, "
          "both legs bit-exact")
    return 0


if __name__ == "__main__":
    sys.exit(main())
