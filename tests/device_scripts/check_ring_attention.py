"""Ring attention (sequence-sharded, ppermute KV rotation) vs reference."""

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels.ref import ref_attention
from repro.parallel.ring_attention import ring_attention


def run(Hq, Hkv, causal, window=None):
    mesh = jax.make_mesh((2, 4), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    B, S, hd = 4, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, hd))
    k = jax.random.normal(ks[1], (B, Hkv, S, hd))
    v = jax.random.normal(ks[2], (B, Hkv, S, hd))
    ref = ref_attention(q, k, v, causal=causal, window=window)

    sh = NamedSharding(mesh, P("data", None, "model", None))
    f = jax.jit(lambda q, k, v: ring_attention(
        q, k, v, causal=causal, window=window, mesh=mesh))
    out = f(*(jax.device_put(a, sh) for a in (q, k, v)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)
    print(f"OK ring attention Hq={Hq} Hkv={Hkv} causal={causal} "
          f"window={window}")


def main():
    assert jax.device_count() >= 8
    run(4, 4, True)
    run(8, 2, True)           # GQA
    run(4, 4, False)
    run(4, 4, True, window=8)  # SWA
    return 0


if __name__ == "__main__":
    sys.exit(main())
