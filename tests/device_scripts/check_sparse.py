"""Sparse-neighborhood Alltoallv acceptance suite (12 CPU devices).

Asserts the ISSUE acceptance criteria for the sparse subsystem
(core.sparse):

* the **bucketed** sparse executor (``SparseA2APlan.forward`` /
  ``reverse``) matches the ``core.simulator`` sparse oracle bit-exactly
  under random sparse counts, across factorizations x variants x round
  orders — valid rows carry the oracle's element tags, rows beyond the
  recv count are zeros (sender padding or skipped-lane zeros, both 0 by
  construction here);
* under **uniform** non-zero counts nothing is skippable and the sparse
  path is bit-exact with the dense ragged path, padding included;
* at <= 10% density the plan's skip accounting (``analyze`` /
  ``exact``) reports **>= 50% of per-round peer exchanges skipped** —
  the subsystem's headline acceptance bound — with
  ``skipped + combined == total`` always;
* the **exact** sparse host mode delivers payloads identical to the
  ragged exact mode and the oracle;
* **dropless MoE** routes through the sparse plan when the tuning DB
  records sparse as the measured ragged-vs-sparse winner
  (``a2a_backend="autotune"``), and its outputs/gradients match the
  mesh-less local oracle.

Exits nonzero on any failure.
"""

import math
import os
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.autotune import TuningDB, ragged_db_key
from repro.core.cache import cart_create
from repro.core.comm import torus_comm
from repro.core.plan import SparseA2APlan, free_plans, plan_cache_entries
from repro.core.ragged import exact_alltoallv
from repro.core.simulator import simulate_direct_alltoallv, \
    simulate_sparse_alltoallv
from repro.core.sparse import sparse_exact_alltoallv
from repro.models.common import init_params
from repro.models.config import ModelConfig
from repro.models.moe import _capacity, _group_geometry, moe_block, \
    moe_ep_comm, moe_specs
from repro.parallel.sharding import ShardingRules, resolve_spec

DIMS = [((3, 4), ("i", "j")), ((2, 3, 2), ("i", "j", "k")),
        ((12,), ("i",))]


def _sparse_counts(p, density, max_count, seed):
    rng = np.random.default_rng(seed)
    c = (rng.integers(1, max_count + 1, size=(p, p))
         * (rng.random((p, p)) < density))
    return c.astype(np.int32)


def _payload(counts, bucket, row, seed):
    """Canonical packed operand: x[s, t, :counts[s, t]] valid rows whose
    values encode (s, t, j) — the oracle's element tags, made floats."""
    p = counts.shape[0]
    x = np.zeros((p, p, bucket) + row, np.float32)
    for s in range(p):
        for t in range(p):
            for j in range(int(counts[s, t])):
                x[s, t, j] = (s * p + t) * bucket + j + 1
    return x


def _expand_order(dims, order):
    active = [i for i, Dk in enumerate(dims) if Dk > 1]
    trivial = [i for i, Dk in enumerate(dims) if Dk == 1]
    return [active[k] for k in order] + trivial


def _reverse_host(plan, mesh):
    axes = tuple(reversed(plan.axis_names))

    def local(x, c):
        recv, rc = plan.reverse(x[0], c[0])
        return recv[None], rc[None]

    return jax.jit(jax.shard_map(local, mesh=mesh,
                                 in_specs=(P(axes), P(axes)),
                                 out_specs=(P(axes), P(axes)),
                                 check_vma=False))


def run_sparse_vs_oracle(dims, names, variant, order, density=0.3,
                         max_count=5, seed=0):
    p = math.prod(dims)
    mesh = cart_create(p, tuple(reversed(dims)), names)
    counts = _sparse_counts(p, density, max_count, seed)
    plan = torus_comm(mesh, names, variant=variant).sparse_all_to_all(
        (2,), "float32", max_count=max_count, density=density,
        round_order=order)
    x = _payload(counts, plan.bucket, (2,), seed)
    recv, rc = plan.host_fn()(jnp.asarray(x), jnp.asarray(counts))
    recv, rc = np.array(recv), np.array(rc)

    # accounting is factorization-specific: use the plan's own dims
    # convention (a Mesh-built factorization records mesh-shape order)
    full_order = None if order is None else _expand_order(plan.dims, order)
    oracle, vol = simulate_sparse_alltoallv(plan.dims, counts.tolist(),
                                            full_order)
    want_direct = simulate_direct_alltoallv(counts.tolist())
    for r in range(p):
        assert oracle[r] == want_direct[r], "oracle self-check failed"
        for s in range(p):
            got = recv[r, s]
            for j, (es, er, ej) in enumerate(oracle[r][s]):
                tag = (es * p + er) * plan.bucket + ej + 1
                np.testing.assert_array_equal(
                    got[j], np.full((2,), tag, np.float32))
            # beyond the count: sender zeros or skipped-lane zeros,
            # both zero for this canonical operand
            np.testing.assert_array_equal(got[int(counts[s, r]):], 0.0)
    np.testing.assert_array_equal(rc, counts.T)

    # plan-side skip accounting == the oracle's volume accounting
    stats = plan.analyze(counts)
    assert stats["skipped_exchanges"] == vol.skipped_exchanges
    assert stats["combined_messages"] == vol.combined_messages
    assert stats["skipped_exchanges"] + stats["combined_messages"] \
        == stats["total_exchanges"]

    # reverse (drain order) is the same permutation, bit-exact
    rrecv, _ = _reverse_host(plan, mesh)(jnp.asarray(x),
                                         jnp.asarray(counts))
    np.testing.assert_array_equal(np.array(rrecv), recv)


def run_uniform_equals_ragged(dims, names, seed=3):
    """Uniform non-zero counts: no lane is skippable, so the sparse path
    must be bit-exact with the dense ragged path — padding included
    (random window contents beyond the count travel identically)."""
    p = math.prod(dims)
    mesh = cart_create(p, tuple(reversed(dims)), names)
    comm = torus_comm(mesh, names)
    sparse = comm.sparse_all_to_all((2,), "float32", max_count=5,
                                    density=1.0)
    ragged = comm.ragged_all_to_all((2,), "float32", max_count=5,
                                    backend="factorized")
    assert sparse.bucket == ragged.bucket
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((p, p, sparse.bucket, 2)).astype(np.float32)
    counts = np.full((p, p), 5, np.int32)
    got, got_rc = sparse.host_fn()(jnp.asarray(x), jnp.asarray(counts))
    want, want_rc = ragged.host_fn()(jnp.asarray(x), jnp.asarray(counts))
    np.testing.assert_array_equal(np.array(got), np.array(want))
    np.testing.assert_array_equal(np.array(got_rc), np.array(want_rc))
    assert sparse.analyze(counts)["skipped_exchanges"] == 0


def run_skip_acceptance():
    """The headline bound: at <= 10% density, >= 50% of the per-round
    peer exchanges are skipped (fixed seeds; measured via plan stats)."""
    for (dims, names), seed in zip(DIMS, (0, 1, 2)):
        p = math.prod(dims)
        plan = torus_comm(dims, names).sparse_all_to_all(
            (2,), "float32", max_count=6, density=0.1)
        counts = _sparse_counts(p, 0.1, 6, seed)
        stats = plan.analyze(counts)
        assert stats["density"] <= 0.25, stats
        assert stats["skip_fraction"] >= 0.5, \
            f"{dims}: skip_fraction {stats['skip_fraction']:.3f} < 0.5"
        assert stats["skipped_exchanges"] + stats["combined_messages"] \
            == stats["total_exchanges"]
        print(f"OK skip acceptance {dims}: "
              f"{stats['skipped_exchanges']}/{stats['total_exchanges']} "
              f"exchanges skipped ({stats['skip_fraction']:.3f} >= 0.5) "
              f"at density {stats['density']:.3f}")


def run_exact_trio(dims, order=None, density=0.2, max_count=4, seed=1):
    """Exact sparse == exact ragged == simulator oracle, payload-wise,
    plus per-message skip accounting on the sparse side."""
    p = math.prod(dims)
    counts = _sparse_counts(p, density, max_count, seed)
    rng = np.random.default_rng(seed + 100)
    rows = [[rng.standard_normal((int(counts[s, t]), 3)).astype(np.float32)
             for t in range(p)] for s in range(p)]
    full_order = None if order is None else _expand_order(dims, order)
    recv_s, cm_s, vol = sparse_exact_alltoallv(rows, dims, full_order)
    recv_r, cm_r = exact_alltoallv(rows, dims, full_order)
    assert cm_s == cm_r == counts.tolist()
    oracle, ovol = simulate_sparse_alltoallv(dims, counts.tolist(),
                                             full_order)
    for r in range(p):
        for s in range(p):
            np.testing.assert_array_equal(recv_s[r][s], recv_r[r][s])
            np.testing.assert_array_equal(recv_s[r][s], rows[s][r])
            assert len(oracle[r][s]) == len(recv_s[r][s])
    assert vol.skipped_exchanges == ovol.skipped_exchanges > 0


def run_dropless_moe_sparse():
    """Dropless MoE through the sparse plan: a tuning-DB record naming
    sparse the measured ragged-vs-sparse winner routes dispatch/combine
    through ``comm.sparse_all_to_all`` under ``a2a_backend="autotune"``;
    outputs and gradients must match the mesh-less local oracle."""
    mesh = jax.make_mesh((2, 3, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=100,
                      n_experts=6, top_k=2, param_dtype="float32",
                      compute_dtype="float32", a2a_backend="autotune",
                      capacity_factor=None)
    p_ = init_params(moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 4, 32))
    B, S, D = x.shape

    # Recompute the dropless chooser's key ingredients (same arithmetic
    # as moe.moe_dropless_a2a_plan / moe_block) and plant a sparse-winner
    # record at exactly that key in a scratch DB.
    axes, G, E_loc, R = _group_geometry(cfg, mesh)
    rules = ShardingRules()
    x_spec = resolve_spec(x.shape, ("batch", None, None), mesh, rules)
    part = x_spec[0]
    batch_axes = () if part is None else \
        ((part,) if isinstance(part, str) else tuple(part))
    n_batch = math.prod([mesh.shape[a] for a in batch_axes]) \
        if batch_axes else 1
    n_loc = (B // n_batch) * S
    C = _capacity(cfg, n_loc, max(cfg.n_experts, G))
    window = E_loc * C
    comm = moe_ep_comm(cfg, mesh, axes)
    lam = cfg.top_k * n_loc / comm.p
    density = min(1.0, max(1e-6, 1.0 - math.exp(-lam)))

    old_env = os.environ.get("REPRO_TUNING_DB")
    with tempfile.TemporaryDirectory(prefix="repro-sparse-moe-") as tmp:
        os.environ["REPRO_TUNING_DB"] = str(Path(tmp) / "tuning.json")
        try:
            free_plans()
            db = TuningDB(Path(tmp) / "tuning.json")
            key = ragged_db_key(comm.dev_key, comm.dims, comm.axis_names,
                                (cfg.d_model,), cfg.cdtype, window,
                                cfg.a2a_variant, density)
            assert db.put(key, {"version": 1,
                                "winner": {"backend": "sparse",
                                           "median_us": 1.0}})

            y_ref, aux_ref = moe_block(p_, x, cfg, mesh=None)
            xg = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
            y, aux = jax.jit(
                lambda p, x: moe_block(p, x, cfg, mesh=mesh))(p_, xg)
            np.testing.assert_allclose(np.array(y), np.array(y_ref),
                                       rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(float(aux), float(aux_ref),
                                       rtol=1e-3)

            # the record must actually have routed through a sparse plan
            sparse_plans = [pl for pl in plan_cache_entries()
                            if isinstance(pl, SparseA2APlan)]
            assert sparse_plans, \
                "no SparseA2APlan in the registry — record not consumed"

            def loss(p, x):
                y, aux = moe_block(p, x, cfg, mesh=mesh)
                return jnp.sum(y ** 2) + 0.01 * aux
            g = jax.jit(jax.grad(loss))(p_, xg)
            for k, v in g.items():
                assert float(jnp.abs(v).sum()) > 0, f"zero grad for {k}"
        finally:
            if old_env is None:
                os.environ.pop("REPRO_TUNING_DB", None)
            else:
                os.environ["REPRO_TUNING_DB"] = old_env
            free_plans()
    print("OK dropless MoE routes through sparse plan (autotune record), "
          "outputs == local oracle, grads nonzero")


def main():
    assert jax.device_count() >= 12, \
        f"need 12 devices, got {jax.device_count()}"
    free_plans()

    n = 0
    for dims, names in DIMS:
        d = len([s for s in dims if s > 1])
        orders = [None, tuple(reversed(range(d)))] if d > 1 else [None]
        for variant in ("natural", "paper"):
            for order in orders:
                run_sparse_vs_oracle(dims, names, variant, order, seed=n)
                n += 1
    print(f"OK bucketed sparse == simulator oracle ({n} cases)")

    for dims, names in DIMS:
        run_uniform_equals_ragged(dims, names)
    print("OK uniform sparse == dense ragged bit-exact")

    run_skip_acceptance()

    run_exact_trio((3, 4))
    run_exact_trio((2, 3, 2), order=(2, 0, 1))
    run_exact_trio((12,))
    print("OK exact sparse == exact ragged == simulator oracle")

    run_dropless_moe_sparse()
    return 0


if __name__ == "__main__":
    sys.exit(main())
