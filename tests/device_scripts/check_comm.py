"""TorusComm acceptance suite (12 CPU devices).

Asserts the communicator redesign end to end:

* ``comm.sub(axes)`` plans are the *identical cached objects* top-level
  comms over the same axes resolve, and execute bit-exactly (the paper's
  dimension-wise split, recursive).
* ``comm.all_gather`` / ``comm.reduce_scatter`` — the new dimension-wise
  gather family — are bit-exact with the ``core.simulator`` oracles and
  with the direct product-communicator collectives, across round orders
  and chunk counts (int payloads: the d-stage reduce association order is
  exact there).  The oracles themselves are pinned to the paper's 5x4 and
  2x3x4 tori (device-free, so they run here too).
* ``torus_comm(p, d=...)`` is the MPI_Dims_create + MPI_Cart_create path:
  it builds the Cartesian mesh itself.
* ``comm.stats()`` unifies factorization / plan / autotune / tuning-DB
  state in one call, and ``comm.free()`` drops the comm's plan slice.

Exits nonzero on any failure.
"""

import itertools
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.cache import cart_create
from repro.core.comm import free_comms, torus_comm
from repro.core.plan import free_plans, plan_cache_stats
from repro.core.simulator import (
    simulate_factorized_allgather,
    simulate_factorized_reduce_scatter,
)

DIMS = [((3, 4), ("i", "j")), ((2, 3, 2), ("i", "j", "k"))]
PAPER_TORI = [(5, 4), (2, 3, 4)]


def _jit(mesh, names, loc, extra_none=0):
    spec = P(tuple(reversed(names)), *([None] * extra_none))
    return jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=spec,
                                 out_specs=spec))


def check_paper_tori_oracles():
    """The oracle pins on the paper's worked tori (device-free)."""
    for dims in PAPER_TORI:
        p = math.prod(dims)
        for order in itertools.permutations(range(len(dims))):
            out, vol = simulate_factorized_allgather(dims, order)
            assert all(out[r] == list(range(p)) for r in range(p)), \
                (dims, order)
            assert vol.total_blocks_sent == p - 1
            out, vol = simulate_factorized_reduce_scatter(dims, order)
            assert all(out[r] == [(s, r) for s in range(p)]
                       for r in range(p)), (dims, order)
            assert vol.total_blocks_sent == p - 1
    print(f"OK simulator oracles on the paper tori {PAPER_TORI}")


def check_allgather(dims, names, backend, order, n_chunks):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    comm = torus_comm(mesh, names)
    plan = comm.all_gather((2, 3), jnp.int32, backend=backend,
                           round_order=order, n_chunks=n_chunks)
    # x[r] = rank r's contribution block
    x = (jnp.arange(p)[:, None, None] * 100
         + jnp.arange(6).reshape(2, 3)).astype(jnp.int32)
    f = _jit(mesh, names, lambda xl: plan.forward(xl[0])[None],
             extra_none=2)
    got = np.array(f(x))            # (p, p, 2, 3): got[r] = gathered buffer
    oracle, _ = simulate_factorized_allgather(
        dims, order if backend != "direct" else None)
    xs = np.array(x)
    for r in range(p):
        want = np.stack([xs[src] for src in oracle[r]])
        np.testing.assert_array_equal(got[r], want)


def check_reduce_scatter(dims, names, backend, order, n_chunks):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    comm = torus_comm(mesh, names)
    plan = comm.reduce_scatter((5,), jnp.int32, backend=backend,
                               round_order=order, n_chunks=n_chunks)
    # x[r, i] = rank r's term for rank i's reduction
    x = (jnp.arange(p)[:, None, None] * 1000 + jnp.arange(p)[None, :, None]
         * 10 + jnp.arange(5)).astype(jnp.int32)
    f = _jit(mesh, names, lambda xl: plan.forward(xl[0])[None],
             extra_none=1)
    got = np.array(f(x))            # (p, 5): got[r] = sum_s x[s, r]
    oracle, _ = simulate_factorized_reduce_scatter(
        dims, order if backend != "direct" else None)
    xs = np.array(x)
    for r in range(p):
        assert oracle[r] == [(s, r) for s in range(p)]
        want = sum(xs[s, r] for s, _t in oracle[r])
        np.testing.assert_array_equal(got[r], want)


def check_sub_comm_parity():
    mesh = cart_create(12, (2, 3, 2), ("i", "j", "k"))
    comm = torus_comm(mesh, ("i", "j", "k"))
    sub = comm.sub(("i", "j"))
    assert sub.dims == (2, 3) and sub.parent is comm
    assert sub.describe()["parent"] == ["i", "j", "k"]
    top = torus_comm(mesh, ("i", "j"))
    p_sub = sub.all_to_all((4,), jnp.float32, backend="factorized")
    p_top = top.all_to_all((4,), jnp.float32, backend="factorized")
    assert p_sub is p_top, "sub-comm plan is not the shared cached object"

    # recursive split: sub of sub
    leaf = sub.sub(("i",))
    assert leaf.dims == (2,) and leaf.parent is sub
    assert leaf.describe()["parent"] == ["i", "j"]
    l_top = torus_comm(mesh, ("i",))
    assert leaf.all_to_all((4,), jnp.float32, backend="direct") is \
        l_top.all_to_all((4,), jnp.float32, backend="direct")

    # gather-family plans built through a sub-comm record their lineage
    ag = sub.all_gather((2,), jnp.int32, backend="factorized")
    assert ag.describe()["parent"] == ["i", "j", "k"]
    print("OK sub-comm plans == top-level plans (shared registry, "
          "recursive split)")


def check_sub_comm_execution():
    """Bit-exactness of a sub-comm all-to-all against the full-comm one
    restricted to the same axes, on a genuinely asymmetric operand."""
    mesh = cart_create(12, (2, 3, 2), ("i", "j", "k"))
    comm = torus_comm(mesh, ("i", "j", "k"))
    sub = comm.sub(("i", "j"))
    top = torus_comm(mesh, ("i", "j"))
    sp = 6
    x = jax.random.normal(jax.random.PRNGKey(0), (sp, sp, 3))

    outs = []
    for plan in (sub.all_to_all((3,), x.dtype, backend="factorized"),
                 top.all_to_all((3,), x.dtype, backend="factorized"),
                 top.all_to_all((3,), x.dtype, backend="direct")):
        f = jax.jit(jax.shard_map(
            lambda xl: plan.forward(xl[0])[None], mesh=mesh,
            in_specs=P(("j", "i"), None, None),
            out_specs=P(("j", "i"), None, None)))
        outs.append(np.array(f(x)))
    np.testing.assert_array_equal(outs[0], outs[2])
    np.testing.assert_array_equal(outs[1], outs[2])
    expected = np.array(x).transpose(1, 0, 2)
    np.testing.assert_array_equal(outs[0], expected)
    print("OK sub-comm execution bit-exact with top-level (and direct)")


def check_dims_create_path():
    comm = torus_comm(12, d=2)
    assert comm.mesh is not None and comm.p == 12
    assert sorted(comm.dims) == [3, 4]
    plan = comm.all_gather((2,), jnp.int32, backend="factorized")
    x = (jnp.arange(12)[:, None] * 7 + jnp.arange(2)).astype(jnp.int32)
    got = np.array(plan.host_fn()(x))
    for r in range(12):
        np.testing.assert_array_equal(got[r], np.array(x))
    print("OK torus_comm(p, d=2) dims_create/cart_create path")


def check_stats_and_free():
    mesh = cart_create(12, (3, 4), ("i", "j"))
    comm = torus_comm(mesh, ("i", "j"), variant="paper")
    comm.all_to_all((4,), jnp.float32, backend="factorized")
    comm.ragged_all_to_all((2,), jnp.float32, max_count=3)
    comm.reduce_scatter((4,), jnp.int32, backend="direct")
    s = comm.stats()
    for section in ("factorization", "plans", "autotune", "tuning_db",
                    "comms", "comm"):
        assert section in s, f"stats() missing {section}"
    assert s["comm"]["plans_live"] == 3
    assert s["plans"]["size"] >= 4      # ragged plan carries nested entries
    assert {"hits", "misses", "size"} <= set(s["plans"])
    assert {"cart_creates", "lookups", "size"} <= set(s["factorization"])
    assert {"db_hits", "db_misses", "timing_executions"} <= \
        set(s["autotune"])
    import json
    json.dumps(s)

    before = plan_cache_stats()["size"]
    comm.free()
    after = plan_cache_stats()["size"]
    assert after <= before - 4, (before, after)   # ragged dropped nested too
    assert comm.stats()["comm"]["freed"]
    print(f"OK unified stats + free(): plan registry {before} -> {after}")


def main():
    assert jax.device_count() >= 12, \
        f"need 12 devices, got {jax.device_count()}"
    free_plans()
    free_comms()

    check_paper_tori_oracles()

    n = 0
    for dims, names in DIMS:
        d = len(dims)
        for backend in ("factorized", "direct"):
            orders = list(itertools.permutations(range(d))) \
                if backend == "factorized" else [None]
            for order in orders:
                for n_chunks in (1, 3):
                    check_allgather(dims, names, backend, order, n_chunks)
                    n += 1
    print(f"OK all-gather == simulator oracle ({n} cases, direct + "
          f"factorized bit-exact)")

    n = 0
    for dims, names in DIMS:
        d = len(dims)
        for backend in ("factorized", "direct"):
            orders = list(itertools.permutations(range(d))) \
                if backend == "factorized" else [None]
            for order in orders:
                for n_chunks in (1, 2):
                    check_reduce_scatter(dims, names, backend, order,
                                         n_chunks)
                    n += 1
    print(f"OK reduce-scatter == simulator oracle ({n} cases, direct + "
          f"factorized bit-exact)")

    check_sub_comm_parity()
    check_sub_comm_execution()
    check_dims_create_path()
    check_stats_and_free()

    stats = plan_cache_stats()
    assert stats["hits"] > 0, f"plan registry never hit: {stats}"
    print(f"OK comm plan registry amortizes: {stats}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
