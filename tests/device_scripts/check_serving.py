"""Disaggregated serving acceptance (12 CPU devices).

Part 1 — a (3,4) device-backed serving torus partitioned into prefill
and decode domains: prompts ingest through the prefill workers, KV
caches migrate to the decode batcher through the jitted
``KVMigrationPlan`` collective (the device ``host_fn`` path — one
bucketed exchange per serving tick, never a per-sequence copy loop),
and every request's output is bit-exact with a colocated
``ContinuousBatcher`` reference.

Part 2 — the same workload with an injected device loss mid-stream:
4 ranks die, ``DisaggregatedServer.rebuild`` re-partitions the 8
survivors and replays every in-flight request (prompt folding) — zero
dropped requests, outputs still identical to the colocated reference.

Exits nonzero on any failure.
"""

import sys

import jax

from repro.core.cache import cart_create
from repro.core.comm import free_comms, torus_comm
from repro.core.plan import free_plans
from repro.models import ModelConfig, build_model
from repro.runtime.serving import (ContinuousBatcher, DisaggregatedServer,
                                   Request)

PROMPTS = [[1, 2, 3], [10, 11], [5, 6, 7, 8], [20], [30, 31, 32],
           [40, 41], [50], [60, 61, 62]]
GENS = [4, 6, 3, 5, 4, 5, 3, 6]


def _model():
    cfg = ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=64,
                      param_dtype="float32", compute_dtype="float32",
                      remat=False)
    model = build_model(cfg)
    return model, jax.jit(model.init)(jax.random.PRNGKey(0))


def _requests():
    return [Request(i, list(p), g, tenant=f"t{i % 3}")
            for i, (p, g) in enumerate(zip(PROMPTS, GENS))]


def _colocated_reference(model, params):
    b = ContinuousBatcher(model, params, max_batch=3, max_seq=48)
    for r in _requests():
        b.submit(r)
    return b.run()


def check_disaggregated(model, params, ref):
    mesh = cart_create(12, (3, 4), ("i", "j"))
    comm = torus_comm(mesh, ("i", "j"))
    srv = DisaggregatedServer(model, params, comm, max_seq=48,
                              decode_batch=3, n_prefill=4,
                              default_quota=2)
    assert srv.topology.comm.mesh is not None   # the device host_fn path
    for r in _requests():
        srv.submit(r)
    done = srv.run()
    assert set(done) == set(range(len(PROMPTS))), sorted(done)
    for rid in ref:
        assert done[rid] == ref[rid], (rid, done[rid], ref[rid])
    topo = srv.topology
    assert topo.migrations > 0 and topo.migrated_rows > 0
    d = srv.stats()["topology"]["plan"]
    assert d["kind"] == "kv_migrate" and d["n_prefill"] == 4
    print(f"OK serving disaggregated: {topo.n_prefill}+{topo.n_decode} "
          f"ranks on (3,4), {topo.migrations} migration collectives "
          f"({topo.migrated_rows} KV rows, inner={topo.plan.inner_kind}) "
          "bit-exact vs colocated")
    comm.free()


def check_rebuild(model, params, ref):
    mesh = cart_create(12, (3, 4), ("i", "j"))
    comm = torus_comm(mesh, ("i", "j"))
    srv = DisaggregatedServer(model, params, comm, max_seq=48,
                              decode_batch=3, n_prefill=4,
                              default_quota=2)
    for r in _requests():
        srv.submit(r)
    for _ in range(8):                   # mid-stream: work in flight
        srv.tick()
    inflight = srv.pending - srv.admission.pending
    n = srv.rebuild(8, n_prefill=3)      # ranks 8..11 die
    assert n > 0 and n >= inflight - len(srv.done)
    assert srv.topology.comm.p == 8 and srv.topology.comm.mesh is not None
    assert srv.topology.n_prefill == 3
    done = srv.run()
    assert set(done) == set(range(len(PROMPTS))), sorted(done)
    for rid in ref:
        assert done[rid] == ref[rid], (rid, done[rid], ref[rid])
    print(f"OK serving rebuild: lost 4 ranks mid-stream, requeued {n} "
          "in-flight requests onto the (2,4) survivor torus, zero "
          "dropped, outputs identical to colocated")
    srv.topology.comm.free()


def main():
    assert jax.device_count() >= 12, \
        f"need 12 devices, got {jax.device_count()}"
    free_plans()
    free_comms()
    model, params = _model()
    ref = _colocated_reference(model, params)
    check_disaggregated(model, params, ref)
    check_rebuild(model, params, ref)
    print("OK serving: disaggregated prefill/decode bit-exact vs "
          "colocated, incl. mid-stream rebuild")
    return 0


if __name__ == "__main__":
    sys.exit(main())
