"""Distributed MoE (EP over factorized all-to-all) vs local oracle.

Mesh (pod=2, data=2, model=2): EP group = data x pod = 4 (d=2 factorized
dispatch — the paper's multi-axis case).  With capacity high enough that
no token drops, the distributed output must match the mesh-less local
computation of the same MoE (same params, same tokens).
"""

import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.common import init_params
from repro.models.moe import moe_block, moe_specs


def run(n_experts, a2a_backend="factorized", a2a_variant="natural"):
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab=100,
                      n_experts=n_experts, top_k=2, capacity_factor=8.0,
                      param_dtype="float32", compute_dtype="float32",
                      a2a_backend=a2a_backend, a2a_variant=a2a_variant)
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 32))

    y_ref, aux_ref = moe_block(p, x, cfg, mesh=None)

    xg = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"))))
    f = jax.jit(lambda p, x: moe_block(p, x, cfg, mesh=mesh))
    y, aux = f(p, xg)
    np.testing.assert_allclose(np.array(y), np.array(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux), float(aux_ref), rtol=1e-3)

    # gradients flow through the collective
    def loss(p, x):
        y, aux = moe_block(p, x, cfg, mesh=mesh)
        return jnp.sum(y ** 2) + 0.01 * aux
    g = jax.jit(jax.grad(loss))(p, xg)
    for k, v in g.items():
        assert float(jnp.abs(v).sum()) > 0, f"zero grad for {k}"
    print(f"OK E={n_experts} backend={a2a_backend} "
          f"(EP group=4, {'replicated' if n_experts < 4 else 'partitioned'})")


def main():
    assert jax.device_count() >= 8
    run(4)             # E == G: one expert per EP rank
    run(8)             # E > G: two experts per rank
    run(2)             # E < G: replicas (grok-style), R=2
    run(4, a2a_backend="direct")
    run(4, a2a_backend="pipelined")
    run(4, a2a_backend="tuned")
    run(4, a2a_backend="overlap")   # pipelined dispatch/FFN/combine
    run(8, a2a_backend="overlap")   # E > G under the overlap engine
    run(2, a2a_backend="overlap")   # replicas under the overlap engine
    run(4, a2a_variant="paper")
    return 0


if __name__ == "__main__":
    sys.exit(main())
