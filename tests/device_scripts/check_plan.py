"""A2APlan equivalence suite (12 CPU devices).

Asserts, for every backend x variant x round order (plus tiled and the
fused overlap form):

* ``A2APlan.forward`` / ``reverse`` / ``tiled`` are bit-exact with the
  legacy free functions (``factorized_all_to_all`` & co.), which are now
  deprecation shims delegating back through plans — the acceptance
  criterion that external callers see identical results.
* every legacy free function emits exactly one ``DeprecationWarning``
  per call site while the plan path emits none.
* repeated plan construction hits the LRU registry (cache amortization).

Exits nonzero on any failure.
"""

import itertools
import math
import sys
import warnings

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import factorized as legacy_f
from repro.core import overlap as legacy_o
from repro.core.cache import cart_create
from repro.core.plan import free_plans, plan_all_to_all, plan_cache_stats

BACKENDS = ("direct", "factorized", "pipelined", "overlap")
DIMS = [((2, 2), ("i", "j")), ((3, 4), ("i", "j")),
        ((2, 3, 2), ("i", "j", "k"))]


def _jit(mesh, names, loc, extra_none=0):
    spec = P(tuple(reversed(names)), *([None] * extra_none))
    return jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=spec,
                                 out_specs=spec))


def _legacy_call(backend, x, names, variant, order, n_chunks):
    if backend == "direct":
        return legacy_f.direct_all_to_all(x, names)
    if backend == "factorized":
        return legacy_f.factorized_all_to_all(x, names, variant=variant,
                                              round_order=order)
    if backend == "pipelined":
        return legacy_o.pipelined_all_to_all(x, names, n_chunks=n_chunks,
                                             variant=variant,
                                             round_order=order)
    return legacy_o.overlapped_all_to_all(x, names, n_chunks=n_chunks,
                                          variant=variant,
                                          round_order=order)


def run_forward_reverse(dims, names, backend, variant, order, n_chunks=2):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    x = (jnp.arange(p)[:, None] * 977 + jnp.arange(p)[None, :])
    x = (x[..., None] * (1 + jnp.arange(6))).astype(jnp.float32)

    plan = plan_all_to_all(mesh, names, x.shape[2:], x.dtype,
                           backend=backend, variant=variant,
                           round_order=order, n_chunks=n_chunks)

    with warnings.catch_warnings():
        # the plan path must never touch the deprecation shims
        warnings.simplefilter("error", DeprecationWarning)
        f_fwd = _jit(mesh, names, lambda xl: plan.forward(xl[0])[None])
        f_rev = _jit(mesh, names, lambda xl: plan.reverse(xl[0])[None])
        got_fwd, got_rev = np.array(f_fwd(x)), np.array(f_rev(x))

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        f_leg = _jit(mesh, names, lambda xl: _legacy_call(
            backend, xl[0], names, variant, order, n_chunks)[None])
        ref = np.array(f_leg(x))
    assert any(issubclass(w.category, DeprecationWarning) for w in caught), \
        f"legacy {backend} free function did not warn"

    expected = np.array(x).transpose(1, 0, 2)
    np.testing.assert_array_equal(ref, expected)
    np.testing.assert_array_equal(got_fwd, expected)
    # reverse runs rounds in drain order: same permutation, bit-exact
    np.testing.assert_array_equal(got_rev, expected)


def run_tiled(dims, names, backend, variant, order, shape=(24, 5),
              split=0, concat=1, n_chunks=2):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    x = jax.random.normal(jax.random.PRNGKey(3), (p,) + shape)

    plan = plan_all_to_all(mesh, names, backend=backend, variant=variant,
                           round_order=order, n_chunks=n_chunks)
    f = _jit(mesh, names, lambda xl: plan.tiled(xl[0], split, concat)[None],
             extra_none=len(shape) - 1)

    def legacy(xl):
        b = xl[0]
        if backend == "direct":
            return legacy_f.direct_all_to_all_tiled(b, names, split,
                                                    concat)[None]
        if backend == "factorized":
            return legacy_f.factorized_all_to_all_tiled(
                b, names, split, concat, variant=variant,
                round_order=order)[None]
        return legacy_o.overlapped_all_to_all_tiled(
            b, names, split, concat, n_chunks=n_chunks, variant=variant,
            round_order=order)[None]

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        g = _jit(mesh, names, legacy, extra_none=len(shape) - 1)
        ref = np.array(g(x))
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)
    np.testing.assert_array_equal(np.array(f(x)), ref)


def run_overlap_fused(dims, names, variant, n_chunks):
    """plan.overlap(fwd/compute/reverse) == legacy overlapped_all_to_all
    with compute_fn + reverse, bit-exact."""
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    x = jax.random.normal(jax.random.PRNGKey(5), (p, p, 4, 6))

    def fn(chunk, _c):
        return chunk * 0.5 - 3.0

    plan = plan_all_to_all(mesh, names, x.shape[2:], x.dtype,
                           backend="overlap", variant=variant,
                           n_chunks=n_chunks)
    f = _jit(mesh, names, lambda xl: plan.overlap(
        xl[0], fn, reverse=True, chunk_axis=2)[None])

    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        g = _jit(mesh, names, lambda xl: legacy_o.overlapped_all_to_all(
            xl[0], names, n_chunks=n_chunks, variant=variant,
            compute_fn=fn, reverse=True, chunk_axis=2)[None])
        ref = np.array(g(x))
    np.testing.assert_array_equal(np.array(f(x)), ref)


def main():
    assert jax.device_count() >= 12, \
        f"need 12 devices, got {jax.device_count()}"
    free_plans()

    n = 0
    for dims, names in DIMS:
        d = len([s for s in dims if s > 1])
        for backend in BACKENDS:
            for variant in ("natural", "paper"):
                for order in itertools.permutations(range(d)):
                    run_forward_reverse(dims, names, backend, variant,
                                        order)
                    n += 1
    print(f"OK plan forward/reverse == legacy free functions ({n} cases)")

    n = 0
    for dims, names in DIMS[:2]:
        for backend in BACKENDS:
            for variant in ("natural", "paper"):
                run_tiled(dims, names, backend, variant, None)
                n += 1
    run_tiled(*DIMS[2], "factorized", "natural", (2, 1, 0),
              shape=(4, 24, 3), split=1, concat=2)
    print(f"OK plan tiled == legacy tiled ({n + 1} cases)")

    for dims, names in DIMS:
        for variant in ("natural", "paper"):
            for n_chunks in (1, 2, 4):
                run_overlap_fused(dims, names, variant, n_chunks)
    print("OK plan fused overlap == legacy overlapped_all_to_all")

    stats = plan_cache_stats()
    assert stats["hits"] > 0, f"plan registry never hit: {stats}"
    print(f"OK plan cache amortizes: {stats}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
