"""Empirical-autotuner acceptance suite (12 CPU devices).

Proves, on a (3, 4) torus with a throwaway tuning DB selected via the
``REPRO_TUNING_DB`` override:

(a) the autotuned plan (measured winner from ``core.autotune``) is
    bit-exact with the analytic ``backend="tuned"`` plan — measured
    selection changes the schedule, never the bytes;
(b) a second ``plan_all_to_all(..., backend="autotune")`` with a warm DB
    performs ZERO timing executions (``autotune_stats`` counter) — the
    search cost is paid once, ever, and the record round-trips through
    JSON to an identical plan;
(c) deleting the DB file falls back to the analytic ``choose_algorithm``
    choice without error (``tuned_from: "model"``).

Exits nonzero on any failure.
"""

import os
import sys
import tempfile
from pathlib import Path

_TMP = tempfile.mkdtemp(prefix="repro-autotune-")
os.environ["REPRO_TUNING_DB"] = str(Path(_TMP) / "tuning.json")

import jax                                                     # noqa: E402
import jax.numpy as jnp                                        # noqa: E402
import numpy as np                                             # noqa: E402

from repro.core.autotune import (autotune, autotune_stats,     # noqa: E402
                                 default_db_path,
                                 reset_autotune_stats)
from repro.core.cache import cart_create, free_all             # noqa: E402
from repro.core.plan import free_plans, plan_all_to_all        # noqa: E402

DIMS, NAMES = (3, 4), ("i", "j")
BLOCK, DTYPE = (48,), jnp.float32


def main():
    assert jax.device_count() >= 12, \
        f"need 12 devices, got {jax.device_count()}"
    assert str(default_db_path()).startswith(_TMP), \
        "REPRO_TUNING_DB override not honored"
    p = 12
    mesh = cart_create(p, DIMS, NAMES)
    x = (jnp.arange(p * p * BLOCK[0]) % 251) \
        .reshape((p, p) + BLOCK).astype(DTYPE)
    expected = np.array(x).transpose(1, 0, 2)

    # ---- (a) measured search; winner bit-exact with the analytic plan ----
    plan = autotune(mesh, NAMES, BLOCK, DTYPE, warmup=1, repeats=3,
                    budget_seconds=60.0)
    assert plan.tuned_from == "measured", plan.tuned_from
    assert autotune_stats()["timing_executions"] > 0
    analytic = plan_all_to_all(mesh, NAMES, BLOCK, DTYPE, backend="tuned")
    assert analytic.tuned_from == "model"
    got = np.array(plan.host_fn(mesh)(x))
    ref = np.array(analytic.host_fn(mesh)(x))
    np.testing.assert_array_equal(got, expected)
    np.testing.assert_array_equal(ref, expected)
    table = plan.describe()["measured"]["table"]
    assert {r["backend"] for r in table} >= {"direct", "factorized",
                                             "overlap"}, table
    assert any(not r["eligible"] for r in table), \
        "factorization sweep rows missing"
    print(f"OK autotuned == analytic bit-exact "
          f"(winner={plan.backend}[n={plan.n_chunks}], "
          f"{len(table)} candidates measured)")

    # ---- (b) warm DB: reconstruction measures nothing ----
    free_plans()
    free_all()
    reset_autotune_stats()
    p2 = plan_all_to_all(mesh, NAMES, BLOCK, DTYPE, backend="autotune")
    stats = autotune_stats()
    assert stats["timing_executions"] == 0, stats
    assert stats["db_hits"] == 1 and stats["db_misses"] == 0, stats
    assert p2.tuned_from == "measured"
    assert (p2.backend, p2.order, p2.n_chunks) == \
        (plan.backend, plan.order, plan.n_chunks)
    np.testing.assert_array_equal(np.array(p2.host_fn(mesh)(x)), expected)
    print(f"OK warm-DB hit rebuilds the winner with zero measurements "
          f"({stats})")

    # ---- (c) DB deleted: analytic fallback, no error, no measurement ----
    default_db_path().unlink()
    free_plans()
    reset_autotune_stats()
    p3 = plan_all_to_all(mesh, NAMES, BLOCK, DTYPE, backend="autotune")
    stats = autotune_stats()
    assert stats["timing_executions"] == 0, stats
    assert stats["db_misses"] == 1, stats
    assert p3.tuned_from == "model"
    assert p3.backend == analytic.backend and p3.n_chunks == \
        analytic.n_chunks
    np.testing.assert_array_equal(np.array(p3.host_fn(mesh)(x)), expected)
    print(f"OK deleted DB falls back to the analytic choice "
          f"(backend={p3.backend}, tuned_from=model)")

    # ---- subset axes: tuned axes spanning only part of the mesh (the
    # MoE EP shape — e.g. EP axes next to an untuned "model" axis); the
    # factorization sweep must rebuild its aux meshes over one subgroup's
    # devices, not the whole mesh ----
    sub_mesh = cart_create(12, (2, 3, 2), ("a", "b", "c"))
    sub_p = 6
    plan_s = autotune(sub_mesh, ("a", "b"), BLOCK, DTYPE, warmup=1,
                      repeats=2, budget_seconds=60.0)
    assert plan_s.tuned_from == "measured" and plan_s.p == sub_p
    xs = (jnp.arange(sub_p * sub_p * BLOCK[0]) % 251) \
        .reshape((sub_p, sub_p) + BLOCK).astype(DTYPE)
    got = np.array(plan_s.host_fn(sub_mesh)(xs))
    np.testing.assert_array_equal(got, np.array(xs).transpose(1, 0, 2))
    assert any(not r["eligible"]
               for r in plan_s.describe()["measured"]["table"]), \
        "subset-axes factorization sweep missing"
    print(f"OK subset-axes autotune (p={sub_p} of 12 devices, "
          f"winner={plan_s.backend})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
