"""Multi-device parity + structural checks for the overlap engine (8 CPU
devices).

* overlap == factorized == direct, bit-exact, for dims in {(2,2), (2,3),
  (2,2,2)} x all round orders x both variants x chunk counts, plus the
  tiled entry point.
* fwd-rounds / compute / reverse-rounds pipelining == the sequential
  composition (a2a; f; a2a), bit-exact.
* the lowered MoE program with ``a2a_backend="overlap"`` emits >= 2
  per-dimension collectives *between* compute stages (hlo_inspect
  .interleave_report), and strictly more collective runs than the
  sequential ``factorized`` program — the structural proof that the
  schedule interleaves rounds with expert compute.

Exits nonzero on any failure.
"""

import itertools
import math
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cache import cart_create
from repro.core.hlo_inspect import interleave_report
from repro.core.plan import plan_all_to_all

DIMS = [((2, 2), ("i", "j")), ((2, 3), ("i", "j")),
        ((2, 2, 2), ("i", "j", "k"))]


def _mesh_fns(dims, names, loc):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    spec = P(tuple(reversed(names)))
    return jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=spec,
                                 out_specs=spec))


def _plan(dims, names, backend, **kw):
    mesh = cart_create(math.prod(dims), dims, names)
    return plan_all_to_all(mesh, names, backend=backend, **kw)


def run_parity(dims, names, variant, round_order, n_chunks, block=(6,)):
    p = math.prod(dims)
    x = (jnp.arange(p)[:, None] * 1000 + jnp.arange(p)[None, :])
    x = (x[..., None] * (1 + jnp.arange(math.prod(block))).reshape(block)
         ).astype(jnp.float32)

    p_ovl = _plan(dims, names, "overlap", n_chunks=n_chunks,
                  variant=variant, round_order=round_order)
    p_fac = _plan(dims, names, "factorized", variant=variant,
                  round_order=round_order)
    p_dir = _plan(dims, names, "direct")
    f_ovl = _mesh_fns(dims, names, lambda xl: p_ovl.forward(xl[0])[None])
    f_fac = _mesh_fns(dims, names, lambda xl: p_fac.forward(xl[0])[None])
    f_dir = _mesh_fns(dims, names, lambda xl: p_dir.forward(xl[0])[None])

    got, fac, ref = np.array(f_ovl(x)), np.array(f_fac(x)), np.array(f_dir(x))
    expected = np.array(x).transpose(1, 0, *range(2, x.ndim))
    np.testing.assert_array_equal(ref, expected)
    np.testing.assert_array_equal(fac, expected)
    np.testing.assert_array_equal(got, expected)


def run_compute_parity(dims, names, n_chunks, variant):
    """fwd / compute / reverse pipeline == sequential (a2a; f; a2a)."""
    p = math.prod(dims)
    x = jax.random.normal(jax.random.PRNGKey(0), (p, p, 4, 6))

    def fn(chunk, _c):
        return chunk * 2.0 + 1.0      # elementwise => chunking-invariant

    p_ovl = _plan(dims, names, "overlap", n_chunks=n_chunks,
                  variant=variant)
    p_fac = _plan(dims, names, "factorized", variant=variant)

    def loc(xl):
        return p_ovl.overlap(xl[0], fn, reverse=True, chunk_axis=2)[None]

    def loc_ref(xl):
        # forward, compute, then the drain-order reverse; rounds commute
        return p_fac.reverse(fn(p_fac.forward(xl[0]), 0))[None]

    f = _mesh_fns(dims, names, loc)
    g = _mesh_fns(dims, names, loc_ref)
    np.testing.assert_array_equal(np.array(f(x)), np.array(g(x)))


def run_tiled(dims, names, shape, split, concat, n_chunks):
    p = math.prod(dims)
    mesh = cart_create(p, dims, names)
    spec = P(tuple(reversed(names)), *([None] * (len(shape) - 1)))
    x = jax.random.normal(jax.random.PRNGKey(1), (p,) + shape)

    p_ovl = _plan(dims, names, "overlap", n_chunks=n_chunks)
    p_dir = _plan(dims, names, "direct")

    def loc(xl):
        return p_ovl.tiled(xl[0], split, concat)[None]

    def locd(xl):
        return p_dir.tiled(xl[0], split, concat)[None]

    f = jax.jit(jax.shard_map(loc, mesh=mesh, in_specs=spec, out_specs=spec))
    g = jax.jit(jax.shard_map(locd, mesh=mesh, in_specs=spec,
                              out_specs=spec))
    np.testing.assert_array_equal(np.array(f(x)), np.array(g(x)))


def moe_interleave_reports():
    """Unoptimized-HLO interleave structure of the MoE program, overlap vs
    sequential factorized backend."""
    from repro.models.config import ModelConfig
    from repro.models.common import init_params
    from repro.models.moe import moe_block, moe_specs

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)

    reports = {}
    for backend in ("overlap", "factorized"):
        cfg = ModelConfig(name="t", family="moe", n_layers=2, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64, vocab=100,
                          n_experts=4, top_k=2, capacity_factor=8.0,
                          param_dtype="float32", compute_dtype="float32",
                          a2a_backend=backend, a2a_chunks=2)
        p = init_params(moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
        x = jax.device_put(
            jax.random.normal(jax.random.PRNGKey(1), (8, 4, 32)),
            NamedSharding(mesh, P(("pod", "data"))))
        lowered = jax.jit(
            lambda p, x: moe_block(p, x, cfg, mesh=mesh)).lower(p, x)
        reports[backend] = interleave_report(lowered.as_text(dialect="hlo"))
    return reports


def main():
    assert jax.device_count() >= 8, \
        f"need 8 devices, got {jax.device_count()}"

    n_cases = 0
    for dims, names in DIMS:
        d = len(dims)
        for variant in ("natural", "paper"):
            for order in itertools.permutations(range(d)):
                for n_chunks in (1, 2, 3):
                    run_parity(dims, names, variant, order, n_chunks)
                    n_cases += 1
    print(f"OK overlap==factorized==direct ({n_cases} cases)")

    for dims, names in DIMS:
        for variant in ("natural", "paper"):
            for n_chunks in (1, 2, 4):
                run_compute_parity(dims, names, n_chunks, variant)
    print("OK fwd/compute/reverse pipeline == sequential composition")

    for dims, names in DIMS:
        run_tiled(dims, names, (24, 5), 0, 0, 2)
        run_tiled(dims, names, (24, 5), 0, 1, 3)
        run_tiled(dims, names, (5, 24), 1, 0, 2)
    print("OK tiled overlap == tiled direct")

    reps = moe_interleave_reports()
    ovl, seq = reps["overlap"], reps["factorized"]
    assert ovl.interleaved_collectives >= 2, \
        f"overlap program not interleaved: {ovl.runs}"
    assert ovl.collective_runs > seq.collective_runs, \
        f"overlap runs {ovl.runs} not finer than sequential {seq.runs}"
    print(f"OK MoE overlap HLO interleaved: "
          f"{ovl.interleaved_collectives} collectives between compute "
          f"stages, runs {ovl.runs} vs sequential {seq.runs}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
