"""Device-free unit tests for the empirical autotuner (core.autotune):
tuning-DB persistence and robustness, the REPRO_TUNING_DB override,
fingerprint-keyed lookup, plan integration (tuned_from provenance,
model fallback), and the per-axis link feedback into the analytic model.

The 12-device measured-search acceptance run lives in
``tests/device_scripts/check_autotune.py`` (see test_multidevice.py).
"""

import json
import math
import warnings

import pytest

from repro.core import cache as core_cache
from repro.core import plan as core_plan
from repro.core.autotune import (
    DB_VERSION,
    TuningDB,
    autotune,
    autotune_stats,
    db_generation,
    default_db_path,
    lookup_measured,
    plan_db_key,
    reset_autotune_stats,
)
from repro.core.cache import cart_create, device_fingerprint, free_all
from repro.core.plan import free_plans, plan_all_to_all
from repro.core.tuning import (
    ICI,
    LinkModel,
    choose_algorithm,
    choose_chunks,
    per_axis_links,
    predict_factorized,
    predict_overlapped,
)


@pytest.fixture(autouse=True)
def _fresh_state(tmp_path, monkeypatch):
    """Every test gets an isolated tuning DB (via the env override), empty
    registries, and zeroed counters."""
    monkeypatch.setenv("REPRO_TUNING_DB", str(tmp_path / "tuning.json"))
    free_plans()
    free_all()
    reset_autotune_stats()
    yield
    free_plans()
    free_all()
    reset_autotune_stats()


def _record(backend="factorized", order=(0,), n_chunks=1, **extra):
    rec = {"version": DB_VERSION,
           "winner": {"backend": backend, "round_order": list(order),
                      "n_chunks": n_chunks, "median_us": 12.5},
           "table": [{"backend": backend, "dims": [1],
                      "round_order": list(order), "n_chunks": n_chunks,
                      "median_us": 12.5, "eligible": True}]}
    rec.update(extra)
    return rec


class TestTuningDB:
    def test_env_override_honored(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_TUNING_DB", str(tmp_path / "other.json"))
        assert default_db_path() == tmp_path / "other.json"
        db = TuningDB()
        assert db.path == tmp_path / "other.json"
        db.put("k", _record())
        assert (tmp_path / "other.json").exists()

    def test_round_trip_persistence(self):
        rec = _record("overlap", (1, 0), 4, measured_links=[
            {"alpha": 2e-6, "bandwidth": 1e9}])
        TuningDB().put("some|key", rec)
        # a fresh handle (fresh process analogue) reads the same record
        got = TuningDB().get("some|key")
        assert got == json.loads(json.dumps(rec))   # JSON round-trip exact
        assert len(TuningDB()) == 1

    def test_put_merges_existing_entries(self):
        TuningDB().put("a", _record())
        TuningDB().put("b", _record("direct", (0,)))
        db = TuningDB()
        assert db.get("a") is not None and db.get("b") is not None

    def test_missing_file_is_empty(self):
        assert TuningDB().load() == {}

    @pytest.mark.parametrize("garbage", [
        "{ not json",                       # corrupt
        '{"version": 1, "entries": ',       # truncated write
        '["a", "list"]',                    # wrong shape
        '{"version": 99, "entries": {}}',   # future version
    ])
    def test_corrupt_db_warns_and_loads_empty(self, garbage):
        db = TuningDB()
        db.path.write_text(garbage)
        with pytest.warns(UserWarning, match="tuning DB"):
            assert db.load() == {}

    def test_corrupt_db_never_crashes_plan_construction(self):
        TuningDB().path.write_text("\x00garbage\x00")
        mesh = cart_create(1, (1,), ("x",))
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            p = plan_all_to_all(mesh, ("x",), (8,), "float32",
                                backend="autotune")
        assert p.tuned_from == "model"   # fell back, did not crash

    def test_clear_deletes_and_missing_ok(self):
        db = TuningDB()
        db.put("k", _record())
        db.clear()
        assert not db.path.exists()
        db.clear()   # second delete is a no-op, not an error

    def test_writes_bump_generation(self):
        g0 = db_generation()
        TuningDB().put("k", _record())
        assert db_generation() == g0 + 1
        TuningDB().clear()
        assert db_generation() == g0 + 2


class TestLookup:
    def _store_for(self, mesh, block=(8,), dtype="float32", **rec_kw):
        key = plan_db_key(device_fingerprint(mesh), (1,), ("x",), block,
                          dtype, "natural")
        TuningDB().put(key, _record(**rec_kw))
        return key

    def test_hit_and_miss_counters(self):
        mesh = cart_create(1, (1,), ("x",))
        fp = device_fingerprint(mesh)
        assert lookup_measured(fp, (1,), ("x",), (8,), "float32",
                               "natural") is None
        self._store_for(mesh)
        assert lookup_measured(fp, (1,), ("x",), (8,), "float32",
                               "natural") is not None
        stats = autotune_stats()
        assert stats == {"searches": 0, "timing_executions": 0,
                         "db_hits": 1, "db_misses": 1}

    def test_fingerprint_mismatch_is_a_miss(self):
        mesh = cart_create(1, (1,), ("x",))
        self._store_for(mesh)
        other_fp = (("not", "this"), ("device", "set"))
        assert lookup_measured(other_fp, (1,), ("x",), (8,), "float32",
                               "natural") is None
        # and through the plan API: falls back to the analytic model
        key = plan_db_key(other_fp, (1,), ("x",), (8,), "float32",
                          "natural")
        assert key != plan_db_key(device_fingerprint(mesh), (1,), ("x",),
                                  (8,), "float32", "natural")

    def test_malformed_record_is_a_miss(self):
        mesh = cart_create(1, (1,), ("x",))
        key = self._store_for(mesh)
        entries = TuningDB().load()
        entries[key] = {"winner": {"backend": "quantum"}}
        TuningDB().put(key, entries[key])
        with pytest.warns(UserWarning, match="malformed"):
            assert lookup_measured(device_fingerprint(mesh), (1,), ("x",),
                                   (8,), "float32", "natural") is None

    def test_key_separates_block_dtype_variant(self):
        base = plan_db_key(None, (2, 3), ("i", "j"), (8,), "float32",
                           "natural")
        assert base != plan_db_key(None, (2, 3), ("i", "j"), (16,),
                                   "float32", "natural")
        assert base != plan_db_key(None, (2, 3), ("i", "j"), (8,),
                                   "int32", "natural")
        assert base != plan_db_key(None, (2, 3), ("i", "j"), (8,),
                                   "float32", "paper")


class TestPlanIntegration:
    def test_miss_falls_back_to_model(self):
        mesh = cart_create(1, (1,), ("x",))
        p = plan_all_to_all(mesh, ("x",), (8,), "float32",
                            backend="autotune")
        assert p.requested_backend == "autotune"
        assert p.tuned_from == "model" and p.measured is None
        assert p.describe()["tuned_from"] == "model"
        assert autotune_stats()["db_misses"] == 1

    def test_autotune_needs_cost_inputs(self):
        with pytest.raises(ValueError, match="autotune"):
            plan_all_to_all((2, 2), ("i", "j"), backend="autotune")

    def test_hit_rebuilds_winner_without_measuring(self):
        mesh = cart_create(1, (1,), ("x",))
        key = plan_db_key(device_fingerprint(mesh), (1,), ("x",), (8,),
                          "float32", "natural")
        TuningDB().put(key, _record("direct", (), 1))
        p = plan_all_to_all(mesh, ("x",), (8,), "float32",
                            backend="autotune")
        assert p.tuned_from == "measured"
        assert p.backend == "direct"
        assert p.measured["median_us"] == 12.5
        assert p.describe()["measured"]["table"][0]["backend"] == "direct"
        assert autotune_stats()["timing_executions"] == 0

    def test_db_write_invalidates_cached_autotune_plan(self):
        # The plan LRU may not keep serving a stale "autotune" resolution
        # after a new measurement (or a delete) lands in the DB.
        mesh = cart_create(1, (1,), ("x",))
        p_model = plan_all_to_all(mesh, ("x",), (8,), "float32",
                                  backend="autotune")
        assert p_model.tuned_from == "model"
        key = plan_db_key(device_fingerprint(mesh), (1,), ("x",), (8,),
                          "float32", "natural")
        TuningDB().put(key, _record("direct", (), 1))
        p_meas = plan_all_to_all(mesh, ("x",), (8,), "float32",
                                 backend="autotune")
        assert p_meas is not p_model
        assert p_meas.tuned_from == "measured"

    def test_unusable_record_falls_back(self):
        # valid-looking record whose round_order cannot apply to this torus
        mesh = cart_create(1, (1,), ("x",))
        key = plan_db_key(device_fingerprint(mesh), (1,), ("x",), (8,),
                          "float32", "natural")
        TuningDB().put(key, _record("factorized", (3, 1, 0, 2), 1))
        with pytest.warns(UserWarning, match="unusable"):
            p = plan_all_to_all(mesh, ("x",), (8,), "float32",
                                backend="autotune")
        assert p.tuned_from == "model"
        # telemetry: the lookup hit is demoted — db_hits counts plans
        # actually built from measurements, and this one wasn't
        stats = autotune_stats()
        assert stats["db_hits"] == 0 and stats["db_misses"] == 1, stats

    def test_measured_links_flow_into_plan(self):
        mesh = cart_create(1, (1,), ("x",))
        key = plan_db_key(device_fingerprint(mesh), (1,), ("x",), (8,),
                          "float32", "natural")
        TuningDB().put(key, _record(
            "factorized", (), 1,
            measured_links=[{"alpha": 3e-6, "bandwidth": 2.5e9}]))
        p = plan_all_to_all(mesh, ("x",), (8,), "float32",
                            backend="autotune")
        assert p.links == (LinkModel(alpha=3e-6, bandwidth=2.5e9),)
        assert p.describe()["links"] == [{"alpha": 3e-6,
                                          "bandwidth": 2.5e9}]

    def test_explicit_backend_has_no_provenance(self):
        p = plan_all_to_all((2, 2), ("i", "j"), (8,), "float32",
                            backend="factorized")
        d = p.describe()
        assert d["tuned_from"] is None and d["measured"] is None


class TestAutotuneSearch:
    """End-to-end measured search on the trivial 1-device torus (cheap —
    real multi-device timings run in check_autotune.py)."""

    def test_search_persists_and_reconstructs(self):
        import jax.numpy as jnp
        mesh = cart_create(1, (1,), ("x",))
        plan = autotune(mesh, ("x",), (8,), jnp.float32, warmup=1,
                        repeats=2, fit_links=False)
        assert plan.tuned_from == "measured"
        stats = autotune_stats()
        assert stats["searches"] == 1
        assert stats["timing_executions"] > 0
        assert default_db_path().exists()
        free_plans()
        reset_autotune_stats()
        again = plan_all_to_all(mesh, ("x",), (8,), jnp.float32,
                                backend="autotune")
        assert again.tuned_from == "measured"
        assert again.backend == plan.backend
        assert autotune_stats()["timing_executions"] == 0

    def test_explicit_db_handle_bypasses_default(self, tmp_path):
        import jax.numpy as jnp
        db = TuningDB(tmp_path / "explicit.json")
        mesh = cart_create(1, (1,), ("x",))
        plan = autotune(mesh, ("x",), (4,), jnp.float32, warmup=0,
                        repeats=1, fit_links=False,
                        include_factorizations=False, db=db)
        assert plan.tuned_from == "measured"
        assert (tmp_path / "explicit.json").exists()
        assert not default_db_path().exists()


class TestPerAxisLinkFeedback:
    """Satellite: per-axis LinkModel overrides flow end-to-end through the
    analytic model (the autotune-measured-bandwidth feedback path)."""

    def test_per_axis_links_broadcast_and_validate(self):
        assert per_axis_links(ICI, 3) == (ICI, ICI, ICI)
        two = (ICI, LinkModel(alpha=1e-5, bandwidth=1e9))
        assert per_axis_links(two, 2) == two
        with pytest.raises(ValueError, match="links"):
            per_axis_links(two, 3)

    def test_uniform_scalar_accepted_everywhere(self):
        dims, b = (4, 4), float(1 << 16)
        p = math.prod(dims)
        assert predict_factorized(dims, ICI, b, p) == \
            predict_factorized(dims, (ICI, ICI), b, p)
        assert predict_overlapped(dims, ICI, b, p, 3) == \
            predict_overlapped(dims, (ICI, ICI), b, p, 3)
        assert choose_chunks(dims, ICI, b) == \
            choose_chunks(dims, (ICI, ICI), b)
        assert choose_algorithm(dims, ICI, b).kind == \
            choose_algorithm(dims, (ICI, ICI), b).kind

    def test_measured_slow_axis_changes_the_choice(self):
        # A measured slow axis must steer chunking exactly like a DCN
        # axis would — the feedback autotune records.
        dims, b = (8, 8), float(1 << 22)
        slow = LinkModel(alpha=5e-5, bandwidth=1e8)
        uniform = choose_chunks(dims, ICI, b, max_chunks=8)
        mixed = choose_chunks(dims, (ICI, slow), b, max_chunks=8)
        p = math.prod(dims)
        t_u = predict_overlapped(dims, (ICI, slow), b, p, uniform)
        t_m = predict_overlapped(dims, (ICI, slow), b, p, mixed)
        assert t_m <= t_u

    def test_legacy_pipelined_choose_chunks_accepts_overrides(self):
        from repro.core.pipelined import choose_chunks as legacy_cc
        from repro.core.tuning import choose_chunks as tuning_cc
        from repro.core.dims import dims_create
        b = float(1 << 22)
        slow = LinkModel(alpha=5e-5, bandwidth=1e8)
        dims = dims_create(64, 2)
        assert legacy_cc(64, 2, b, ICI, 8, links=(ICI, slow)) == \
            tuning_cc(dims, (ICI, slow), b, max_chunks=8)
        # uniform legacy form unchanged
        assert legacy_cc(64, 2, b, ICI, 8) == \
            tuning_cc(dims, ICI, b, max_chunks=8)
