"""Device-free unit tests for the TorusComm API root (core.comm):
communicator construction/caching, the recursive dimension-wise split,
collective factories (incl. the new all-gather / reduce-scatter family),
describe() goldens, unified stats, and lifecycle.

Multi-device execution parity (sub-comm vs top-level, gather family vs
the simulator oracles) runs in ``tests/device_scripts/check_comm.py``
(see test_multidevice.py).
"""

import json

import pytest

from repro.core import cache as core_cache
from repro.core import comm as core_comm
from repro.core import plan as core_plan
from repro.core.cache import cart_create, free_all, set_cache_capacity
from repro.core.comm import (
    AllGatherPlan,
    ReduceScatterPlan,
    free_comms,
    torus_comm,
    unified_stats,
)
from repro.core.plan import (
    A2APlan,
    RaggedA2APlan,
    free_plans,
    plan_all_to_all,
    plan_cache_stats,
    plan_ragged_all_to_all,
    set_plan_cache_capacity,
)
from repro.core.tuning import DCN, ICI, choose_dimwise_algorithm


@pytest.fixture(autouse=True)
def _fresh_registries():
    free_comms()
    free_plans()
    free_all()
    core_plan._PLANS.stats.update(hits=0, misses=0, evictions=0)
    core_cache._REGISTRY.stats.update(hits=0, misses=0, evictions=0)
    core_comm._COMMS.stats.update(hits=0, misses=0, evictions=0)
    old_plan_cap = core_plan._PLANS.capacity
    old_fact_cap = core_cache._REGISTRY.capacity
    yield
    set_plan_cache_capacity(old_plan_cap)
    set_cache_capacity(old_fact_cap)
    free_comms()
    free_plans()
    free_all()


class TestConstruction:
    def test_dims_path_identity(self):
        a = torus_comm((2, 3), ("i", "j"))
        b = torus_comm((2, 3), ("i", "j"))
        assert a is b
        assert a.dims == (2, 3) and a.axis_names == ("i", "j")
        assert a.p == 6 and a.d == 2 and a.mesh is None

    def test_variant_separates_comms(self):
        a = torus_comm((2, 2), ("i", "j"))
        b = torus_comm((2, 2), ("i", "j"), variant="paper")
        assert a is not b and b.variant == "paper"

    def test_mesh_path_keyed_by_fingerprint(self):
        m1 = cart_create(1, (1,), ("x",))
        m2 = cart_create(1, (1,), ("x",))   # rebuilt mesh, same devices
        assert torus_comm(m1, ("x",)) is torus_comm(m2, ("x",))

    def test_validation(self):
        with pytest.raises(ValueError, match="dims for"):
            torus_comm((2, 3, 4), ("i", "j"))
        with pytest.raises(ValueError, match="axis_names or d"):
            torus_comm(cart_create(1, (1,), ("x",)))
        with pytest.raises(ValueError, match="needs d"):
            torus_comm(6)

    def test_context_manager_frees(self):
        with torus_comm((2, 2), ("i", "j"), variant="paper") as comm:
            comm.all_to_all((4,), "float32", backend="direct")
            assert plan_cache_stats()["size"] == 1
        assert comm._freed
        assert plan_cache_stats()["size"] == 0


class TestSub:
    def test_split_and_recursion(self):
        comm = torus_comm((2, 3, 4), ("i", "j", "k"))
        sub = comm.sub(("i", "k"))
        assert sub.dims == (2, 4) and sub.parent is comm
        assert comm.sub(("i", "k")) is sub
        leaf = sub.sub(("k",))
        assert leaf.dims == (4,) and leaf.parent is sub

    def test_validation(self):
        comm = torus_comm((2, 3), ("i", "j"))
        with pytest.raises(ValueError, match="not in communicator"):
            comm.sub(("z",))
        with pytest.raises(ValueError, match="duplicate"):
            comm.sub(("i", "i"))

    def test_same_axes_children_of_different_parents_are_distinct(self):
        # Two parents over different tori split into same-axes children:
        # those must be distinct comms with the right lineage, and one
        # parent's free() must not tear down the other's child.
        c1 = torus_comm((2, 3), ("i", "j"))
        c2 = torus_comm((2, 4), ("i", "j"))
        s1, s2 = c1.sub(("i",)), c2.sub(("i",))
        assert s1 is not s2
        assert s1.parent is c1 and s2.parent is c2
        c1.free()
        assert not s2._freed and c2.sub(("i",)) is s2

    def test_describe_golden(self):
        comm = torus_comm((4, 2), ("i", "j"))
        sub = comm.sub(("j",))
        sub.all_to_all((4,), "float32", backend="direct")
        assert sub.describe() == {
            "kind": "comm",
            "axes": ["j"],
            "dims": [2],
            "p": 2,
            "d": 1,
            "variant": "natural",
            "parent": ["i", "j"],
            "device_backed": False,
            "plans": 1,
            "subs": [],
            "rebuilt_from": None,
            "tuning_migrated": 0,
        }
        d = comm.describe()
        assert d["parent"] is None and d["subs"] == [["j"]]
        json.dumps(d)

    def test_sub_plans_are_top_level_plans(self):
        comm = torus_comm((2, 3), ("i", "j"))
        sub = comm.sub(("i",))
        top = torus_comm((2,), ("i",))
        a = sub.all_to_all((8,), "float32", backend="factorized")
        b = top.all_to_all((8,), "float32", backend="factorized")
        assert a is b
        r1 = sub.ragged_all_to_all((2,), "float32", max_count=3)
        r2 = top.ragged_all_to_all((2,), "float32", max_count=3)
        assert r1 is r2


class TestCollectiveFactories:
    def test_all_to_all_matches_delegator(self):
        comm = torus_comm((2, 3), ("i", "j"))
        a = comm.all_to_all((8,), "float32", backend="factorized")
        b = plan_all_to_all((2, 3), ("i", "j"), (8,), "float32",
                            backend="factorized")
        assert isinstance(a, A2APlan) and a is b

    def test_ragged_matches_delegator(self):
        comm = torus_comm((2, 3), ("i", "j"))
        a = comm.ragged_all_to_all((4,), "float32", max_count=5)
        b = plan_ragged_all_to_all((2, 3), ("i", "j"), (4,), "float32",
                                   max_count=5)
        assert isinstance(a, RaggedA2APlan) and a is b

    def test_gather_family_cached(self):
        comm = torus_comm((2, 3), ("i", "j"))
        ag = comm.all_gather((4,), "int32", backend="factorized")
        assert isinstance(ag, AllGatherPlan)
        assert comm.all_gather((4,), "int32", backend="factorized") is ag
        assert ag.describe()["cache"] == "hit"
        rs = comm.reduce_scatter((4,), "int32", backend="direct")
        assert isinstance(rs, ReduceScatterPlan)
        assert rs is not ag

    def test_gather_backend_validation(self):
        comm = torus_comm((2, 2), ("i", "j"))
        with pytest.raises(ValueError, match="backend"):
            comm.all_gather((4,), "int32", backend="overlap")
        with pytest.raises(ValueError, match="tuned"):
            comm.reduce_scatter(backend="tuned")
        with pytest.raises(ValueError, match="permutation"):
            comm.all_gather((4,), "int32", backend="factorized",
                            round_order=(0, 0))


class TestGatherDescribeGoldens:
    def test_allgather_golden(self):
        comm = torus_comm((4, 2), ("i", "j"))
        plan = comm.all_gather((16, 8), "bfloat16", backend="factorized",
                               round_order=(1, 0), n_chunks=3,
                               links=(ICI, DCN))
        d = plan.describe()
        pred = d.pop("predicted_seconds")
        assert pred > 0
        assert d == {
            "kind": "allgather",
            "axes": ["i", "j"],
            "dims": [4, 2],
            "p": 8,
            "d": 2,
            "backend": "factorized",
            "requested_backend": "factorized",
            "variant": "natural",
            "round_order": [1, 0],
            "n_chunks": 3,
            "block_shape": [16, 8],
            "dtype": "bfloat16",
            "block_bytes": 256,
            "links": [{"alpha": ICI.alpha, "bandwidth": ICI.bandwidth},
                      {"alpha": DCN.alpha, "bandwidth": DCN.bandwidth}],
            "tuned_from": None,
            "parent": None,
            "cache": "miss",
        }
        json.dumps(plan.describe())

    def test_reduce_scatter_golden_via_sub(self):
        comm = torus_comm((4, 2), ("i", "j"))
        plan = comm.sub(("i",)).reduce_scatter((8,), "float32",
                                               backend="direct")
        d = plan.describe()
        assert d["kind"] == "reduce_scatter"
        assert d["axes"] == ["i"]
        assert d["parent"] == ["i", "j"]
        assert d["tuned_from"] is None
        assert d["backend"] == "direct"
        assert d["predicted_seconds"] > 0
        json.dumps(d)

    def test_predictors_price_active_stages_with_trivial_dims(self):
        # round_order permutes the ACTIVE stages (the kernel/plan
        # convention): with a trivial dim present both size-4 stages
        # must be priced, under either permutation.
        from repro.core.tuning import predict_allgather, \
            predict_reduce_scatter

        for predict in (predict_allgather, predict_reduce_scatter):
            t_id = predict((1, 4, 4), ICI, 1024.0, 16, round_order=(0, 1))
            t_rev = predict((1, 4, 4), ICI, 1024.0, 16, round_order=(1, 0))
            t_flat = predict((4, 4), (ICI, ICI), 1024.0, 16)
            assert t_id == pytest.approx(t_rev)   # uniform links commute
            assert t_id == pytest.approx(t_flat)  # trivial dim is free
            with pytest.raises(ValueError, match="permutation"):
                predict((1, 4, 4), ICI, 1024.0, 16, round_order=(0, 1, 2))

    def test_tuned_matches_choose_dimwise_algorithm(self):
        dims, links = (16, 4), (ICI, DCN)
        for kind, method in (("allgather", "all_gather"),
                             ("reduce_scatter", "reduce_scatter")):
            for bytes_ in (4, 1 << 16, 1 << 24):
                comm = torus_comm(dims, ("i", "j"))
                plan = getattr(comm, method)((bytes_,), "int8",
                                             backend="tuned", links=links)
                sched = choose_dimwise_algorithm(kind, dims, links,
                                                 float(bytes_))
                assert plan.backend == sched.kind
                assert plan.tuned_from == "model"
                assert plan.describe()["predicted_seconds"] == \
                    pytest.approx(sched.predicted_seconds)


class TestStatsAndLifecycle:
    def test_unified_stats_sections(self):
        comm = torus_comm((2, 3), ("i", "j"))
        comm.all_to_all((4,), "float32", backend="direct")
        s = comm.stats()
        assert set(s) == {"factorization", "plans", "autotune",
                          "tuning_db", "comms", "comm", "telemetry"}
        assert {"metrics", "tracer", "drift"} <= set(s["telemetry"])
        assert s["plans"]["size"] == 1
        assert s["comm"]["plans_live"] == 1
        assert {"path", "generation"} <= set(s["tuning_db"])
        json.dumps(s)
        # the module-level form (what dryrun records) has no comm section
        assert "comm" not in unified_stats()

    def test_free_drops_plan_slice_and_recurses(self):
        comm = torus_comm((2, 3), ("i", "j"), variant="paper")
        comm.all_to_all((4,), "float32", backend="direct")
        comm.sub(("i",)).all_gather((2,), "int32", backend="factorized")
        comm.ragged_all_to_all((2,), "float32", max_count=3)
        assert plan_cache_stats()["size"] == 5   # dense+ag+ragged+nested(2)
        comm.free()
        assert plan_cache_stats()["size"] == 0
        # a fresh lookup builds a new communicator, not the freed one
        again = torus_comm((2, 3), ("i", "j"), variant="paper")
        assert again is not comm and not again._freed

    def test_free_is_idempotent(self):
        comm = torus_comm((2, 2), ("i", "j"), variant="paper")
        comm.free()
        comm.free()
        assert comm.stats()["comm"]["freed"]

    def test_stale_free_does_not_evict_successor(self):
        c1 = torus_comm((2, 2), ("i", "j"), variant="paper")
        c1.free()
        c2 = torus_comm((2, 2), ("i", "j"), variant="paper")
        c1.free()   # stale second free must not retire c2's entry
        assert torus_comm((2, 2), ("i", "j"), variant="paper") is c2

    def test_db_handle_is_part_of_comm_identity(self):
        from repro.core.autotune import TuningDB

        default = torus_comm((2, 2), ("i", "j"))
        custom = torus_comm((2, 2), ("i", "j"),
                            db=TuningDB("/tmp/repro-test-tuning.json"))
        # a custom-DB comm must neither reuse nor shadow the default one
        assert custom is not default and custom._db is not None
        assert torus_comm((2, 2), ("i", "j")) is default
        assert custom.sub(("i",))._db is custom._db

    def test_single_linkmodel_broadcasts_in_every_family(self):
        comm = torus_comm((2, 3), ("i", "j"))
        assert comm.all_to_all((4,), "float32", backend="factorized",
                               links=ICI).links == (ICI, ICI)
        assert comm.all_gather((4,), "int32", backend="factorized",
                               links=ICI).links == (ICI, ICI)
        assert comm.ragged_all_to_all((2,), "float32", max_count=3,
                                      links=DCN).data.links == (DCN, DCN)


class TestDelegatorsUseImplicitComm:
    def test_plan_all_to_all_builds_comm_entry(self):
        plan_all_to_all((2, 3), ("i", "j"), (8,), "float32",
                        backend="direct")
        assert len(core_comm._COMMS) == 1
        # and the same plan key hits through either spelling
        comm = torus_comm((2, 3), ("i", "j"))
        p = comm.all_to_all((8,), "float32", backend="direct")
        assert p.describe()["cache"] == "hit"


class TestPartition:
    """The MPI_Comm_split analogue by device range — the serving spine's
    prefill/decode domain split."""

    def test_device_range_split(self):
        comm = torus_comm((2, 3), ("i", "j"))
        pre, dec = comm.partition(4)
        assert pre.p == 4 and dec.p == 2
        assert pre.parent is comm and dec.parent is comm
        assert pre.dims == (2, 2) and dec.dims == (1, 2)
        assert pre.axis_names == ("pre0", "pre1")
        assert dec.axis_names == ("dec0", "dec1")
        # device-agnostic parent -> device-agnostic children
        assert pre.mesh is None and dec.mesh is None

    def test_cached_and_freed_with_parent(self):
        comm = torus_comm((2, 3), ("i", "j"))
        pre, dec = comm.partition(4)
        again = comm.partition(4)
        assert again[0] is pre and again[1] is dec
        # distinct split point -> distinct pair
        other = comm.partition(2)
        assert other[0] is not pre
        # freeing a child invalidates the cached pair; re-partition rebuilds
        pre.free()
        pre2, dec2 = comm.partition(4)
        assert pre2 is not pre
        # children die with the parent
        comm.free()
        assert pre2._freed and dec2._freed and other[0]._freed

    def test_validation(self):
        comm = torus_comm((2, 3), ("i", "j"))
        with pytest.raises(ValueError, match="n_first"):
            comm.partition(0)
        with pytest.raises(ValueError, match="n_first"):
            comm.partition(6)
        with pytest.raises(ValueError, match="prefixes"):
            comm.partition(3, prefixes=("a", "a"))

    def test_partition_degree_override(self):
        comm = torus_comm((2, 3), ("i", "j"))
        pre, dec = comm.partition(4, d=1)
        assert pre.dims == (4,) and dec.dims == (2,)

    def test_kv_migration_factory_notes_plan(self):
        from repro.core.plan import plan_kv_migration

        comm = torus_comm((2, 3), ("i", "j"))
        plan = comm.kv_migration((4,), max_count=5, n_prefill=2)
        assert plan.kind == "kv_migrate" and plan.n_prefill == 2
        assert plan._registry_key in comm._plan_keys
        # the module-level delegator resolves to the same registry entry
        again = plan_kv_migration((2, 3), ("i", "j"), (4,),
                                  max_count=5, n_prefill=2)
        assert again is plan
        # comm teardown drops the plan slice
        comm.free()
        fresh = plan_kv_migration((2, 3), ("i", "j"), (4,),
                                  max_count=5, n_prefill=2)
        assert fresh is not plan
