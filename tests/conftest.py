import os
import sys
from pathlib import Path

# Make src/ and tests/ importable regardless of invocation directory.
ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(Path(__file__).parent))

# Keep the test session single-device (policy: XLA_FLAGS only in
# subprocesses and launch/dryrun.py). Guard against leakage.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), \
    "test session must not force a device count; use tests/_subproc.py"
os.environ.setdefault("JAX_PLATFORMS", "cpu")
