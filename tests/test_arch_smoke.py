"""Per-architecture smoke tests: reduced config of the same family, one
forward + train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import build_model, make_train_step
from repro.optim import AdamW, AdamWConfig

KEY = jax.random.PRNGKey(0)


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks,
             "mask": jnp.ones((B, S), jnp.float32)}
    if cfg.frontend is not None or cfg.encoder_layers:
        batch["frontend_embeds"] = jax.random.normal(
            KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_full_config_exact_dims(arch):
    """Pin the FULL configs to the assigned architecture table."""
    expected = {
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072, 8),
        "phi3.5-moe-42b": (32, 4096, 32, 8, 6400, 32064, 16),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536, 16),
        "xlstm-1.3b": (48, 2048, 4, 4, 0, 50304, 0),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553, 0),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544, 0),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000, 0),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400, 0),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936, 0),
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865, 0),
    }[arch]
    cfg = get_config(arch)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab, cfg.n_experts)
    assert got == expected


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch(cfg)

    # forward: logits shape + finite
    if cfg.encoder_layers:
        logits, aux = model.forward(
            params, batch["tokens"],
            frontend_embeds=batch["frontend_embeds"])
    elif cfg.frontend is not None:
        logits, aux = model.forward(
            params, batch["tokens"],
            frontend_embeds=batch["frontend_embeds"])
    else:
        logits, aux = model.forward(params, batch["tokens"])
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), f"NaN logits for {arch}"

    # one train step
    opt = AdamW(AdamWConfig(lr=1e-3))
    step = jax.jit(make_train_step(model, opt))
    params2, opt2, metrics = step(params, opt.init(params), batch)
    assert np.isfinite(float(metrics["total_loss"])), arch
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved, f"no parameter update for {arch}"


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "jamba-v0.1-52b",
                                  "xlstm-1.3b", "h2o-danube-1.8b",
                                  "whisper-tiny"])
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg)
    params = model.init(KEY)
    B = 2
    caches = model.init_caches(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    if cfg.encoder_layers:
        fe = jax.random.normal(KEY, (B, cfg.n_frontend_tokens, cfg.d_model))
        memory = model.encode(params, fe)
        logits, caches = model.decode_step(params, tok, caches, memory)
        logits, caches = model.decode_step(params, tok, caches, memory)
    else:
        logits, caches = model.decode_step(params, tok, caches)
        logits, caches = model.decode_step(params, tok, caches)
    assert logits.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
