"""Substrate tests: optimizer, schedules, transforms, data, checkpoint,
trainer (fault tolerance)."""

import shutil
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _property import given, settings, st

from repro.checkpoint import CheckpointManager, latest_step, \
    restore_checkpoint, save_checkpoint
from repro.data import CopyTaskConfig, DataConfig, SyntheticLM, \
    make_copy_task_batch, make_lm_batch
from repro.models import ModelConfig, build_model, make_train_step
from repro.optim import (AdamW, AdamWConfig, compress_dequantize,
                         cosine_with_warmup, global_norm)
from repro.runtime import Trainer, TrainerConfig
from repro.runtime.watchdog import StragglerWatchdog


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

class TestAdamW:
    def test_decreases_quadratic(self):
        opt = AdamW(AdamWConfig(lr=0.1, weight_decay=0.0))
        params = {"w": jnp.array([3.0, -2.0])}
        state = opt.init(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, state, _ = opt.update(params, grads, state)
        assert float(jnp.abs(params["w"]).max()) < 1e-2

    def test_clipping(self):
        opt = AdamW(AdamWConfig(lr=0.0, clip_norm=1.0))
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        _, _, gnorm = opt.update(params, {"w": jnp.full(4, 100.0)}, state)
        assert float(gnorm) == pytest.approx(200.0)

    def test_moments_match_param_structure(self):
        opt = AdamW()
        params = {"a": jnp.zeros((2, 3)), "b": {"c": jnp.zeros(5)}}
        st_ = opt.init(params)
        assert jax.tree.structure(st_["mu"]) == jax.tree.structure(params)

    def test_schedule(self):
        f = cosine_with_warmup(1.0, 10, 100, final_frac=0.1)
        assert float(f(jnp.array(0))) == pytest.approx(0.0)
        assert float(f(jnp.array(10))) == pytest.approx(1.0)
        assert float(f(jnp.array(100))) == pytest.approx(0.1, rel=1e-3)


class TestTransforms:
    @given(st.integers(1, 5))
    @settings(max_examples=10, deadline=None)
    def test_compression_bounded_error(self, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (1000,))
        y = compress_dequantize({"g": x})["g"]
        blockmax = float(jnp.abs(x).max())
        assert float(jnp.abs(y - x).max()) <= blockmax / 127.0 + 1e-6

    def test_global_norm(self):
        t = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
        assert float(global_norm(t)) == pytest.approx(
            np.sqrt(4 * 9 + 9 * 16))


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

class TestData:
    def test_deterministic(self):
        cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
        b1 = make_lm_batch(cfg, 7)
        b2 = make_lm_batch(cfg, 7)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = make_lm_batch(cfg, 8)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_tokens_in_range(self):
        cfg = DataConfig(vocab=50, seq_len=64, global_batch=8)
        b = make_lm_batch(cfg, 0)
        assert int(b["tokens"].min()) >= 0
        assert int(b["tokens"].max()) < 50

    def test_copy_task_structure(self):
        cfg = CopyTaskConfig(vocab=32, seq_len=16, global_batch=2)
        b = make_copy_task_batch(cfg, 3)
        plen = cfg.plen
        # labels in the scored region == tokens from the prefix
        np.testing.assert_array_equal(
            np.asarray(b["labels"][:, plen:2 * plen]),
            np.asarray(b["tokens"][:, :plen]))

    def test_cursor_roundtrip(self):
        cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
        s = SyntheticLM(cfg)
        s.next(), s.next()
        d = s.state_dict()
        s2 = SyntheticLM(cfg)
        s2.load_state_dict(d)
        np.testing.assert_array_equal(s.next()["tokens"],
                                      s2.next()["tokens"])


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip_and_retention(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        for s in (1, 2, 3, 4):
            save_checkpoint(tmp_path, s, tree, {"step": s}, keep=2)
        assert latest_step(tmp_path) == 4
        steps = sorted(int(p.name[5:]) for p in Path(tmp_path).iterdir()
                       if p.name.startswith("step_"))
        assert steps == [3, 4]
        out, extra, step = restore_checkpoint(tmp_path, None, tree)
        assert step == 4 and extra["step"] == 4
        np.testing.assert_array_equal(out["a"], tree["a"])
        assert out["b"]["c"].dtype == jnp.bfloat16

    def test_corruption_detected(self, tmp_path):
        tree = {"a": jnp.ones(8)}
        path = save_checkpoint(tmp_path, 1, tree)
        leaf = next(path.glob("leaf_*.zst"))
        from repro.checkpoint.store import zstd   # module or zlib fallback
        bad = zstd.ZstdCompressor().compress(
            np.zeros(8, np.float32).tobytes())
        leaf.write_bytes(bad)
        with pytest.raises(IOError):
            restore_checkpoint(tmp_path, 1, tree)

    def test_missing_leaf_detected(self, tmp_path):
        save_checkpoint(tmp_path, 1, {"a": jnp.ones(2)})
        with pytest.raises(KeyError):
            restore_checkpoint(tmp_path, 1, {"zz": jnp.ones(2)})

    def test_async_manager(self, tmp_path):
        m = CheckpointManager(tmp_path)
        m.save_async(5, {"x": jnp.arange(3)}, {"step": 5})
        m.wait()
        assert m.latest() == 5


# ---------------------------------------------------------------------------
# trainer / fault tolerance
# ---------------------------------------------------------------------------

def _tiny_setup(tmpdir, total=60, ckpt_every=20):
    cfg = ModelConfig(name="tiny", family="dense", n_layers=1, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab=64,
                      param_dtype="float32", compute_dtype="float32",
                      remat=False)
    model = build_model(cfg)
    opt = AdamW(AdamWConfig(lr=1e-3, weight_decay=0.0))
    params = model.init(jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt))
    data = SyntheticLM(CopyTaskConfig(vocab=64, seq_len=16, global_batch=8),
                       task="copy")
    tr = Trainer(TrainerConfig(total_steps=total, checkpoint_dir=str(tmpdir),
                               checkpoint_every=ckpt_every, log_every=10,
                               async_checkpoint=False),
                 step, data, params, opt.init(params))
    return model, opt, step, tr


class TestTrainer:
    def test_learns_copy_task(self, tmp_path):
        cfg = ModelConfig(name="tiny", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                          vocab=64, param_dtype="float32",
                          compute_dtype="float32", remat=False)
        model = build_model(cfg)
        opt = AdamW(AdamWConfig(lr=cosine_with_warmup(3e-3, 20, 300),
                                weight_decay=0.0))
        params = model.init(jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(model, opt))
        data = SyntheticLM(CopyTaskConfig(vocab=64, seq_len=32,
                                          global_batch=16), task="copy")
        tr = Trainer(TrainerConfig(total_steps=300,
                                   checkpoint_dir=str(tmp_path),
                                   checkpoint_every=1000, log_every=50,
                                   async_checkpoint=False),
                     step, data, params, opt.init(params))
        tr.run()
        losses = [r["ce_loss"] for r in tr.metrics_log]
        assert losses[-1] < 0.5 * losses[0], losses

    def test_bit_exact_restart(self, tmp_path):
        model, opt, step, tr = _tiny_setup(tmp_path, total=40,
                                           ckpt_every=20)
        tr.run()
        # crash simulation: fresh trainer restores at step 40... restore
        # from the *intermediate* step-20 checkpoint and replay.
        tree, extra, _ = tr.ckpt.restore(tr._state_tree(), step=20)
        data2 = SyntheticLM(CopyTaskConfig(vocab=64, seq_len=16,
                                           global_batch=8), task="copy")
        tr2 = Trainer(TrainerConfig(total_steps=40,
                                    checkpoint_dir=str(tmp_path) + "_x",
                                    checkpoint_every=100, log_every=10,
                                    async_checkpoint=False),
                      step, data2, tree["params"], tree["opt_state"],
                      step=20)
        tr2.data.load_state_dict(extra["data"])
        tr2.run()
        for a, b in zip(jax.tree.leaves(tr.params),
                        jax.tree.leaves(tr2.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_watchdog_classification(self):
        w = StragglerWatchdog(min_samples=5)
        for i in range(20):
            assert w.observe(i, 0.1 + 0.001 * (i % 3)) == "ok"
        assert w.observe(20, 0.4) == "straggler"
        assert w.observe(21, 5.0) == "hang"
        kinds = [e[0] for e in w.events]
        assert kinds == ["straggler", "hang"]

    def test_hang_aborts_with_checkpoint(self, tmp_path, monkeypatch):
        model, opt, step, tr = _tiny_setup(tmp_path, total=60,
                                           ckpt_every=1000)
        calls = {"n": 0}
        orig = step

        def slow_step(p, o, b):
            calls["n"] += 1
            out = orig(p, o, b)
            if calls["n"] == 30:
                import time
                time.sleep(1.5)
            return out

        tr.train_step = slow_step
        with pytest.raises(RuntimeError, match="hang"):
            tr.run()
        assert tr.ckpt.latest() == 30   # checkpointed at the abort

    def test_grad_accum_matches_full_batch(self):
        cfg = ModelConfig(name="tiny", family="dense", n_layers=1,
                          d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                          vocab=64, param_dtype="float32",
                          compute_dtype="float32", remat=False)
        model = build_model(cfg)
        opt = AdamW(AdamWConfig(lr=1e-2, weight_decay=0.0))
        params = model.init(jax.random.PRNGKey(0))
        batch = make_copy_task_batch(
            CopyTaskConfig(vocab=64, seq_len=16, global_batch=8), 0)
        s1 = jax.jit(make_train_step(model, opt))
        s2 = jax.jit(make_train_step(model, opt, grad_accum=4))
        p1, _, m1 = s1(params, opt.init(params), batch)
        p2, _, m2 = s2(params, opt.init(params), batch)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)
