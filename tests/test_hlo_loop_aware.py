"""Loop-aware HLO analysis: exactness on known programs (single device)."""

import jax
import jax.numpy as jnp
import pytest

from repro.core.hlo_inspect import (collective_group_stride,
                                    loop_aware_analysis)


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


class TestLoopAwareFlops:
    def test_scan_flops_exact(self):
        def body(c, x):
            return c @ x, jnp.sum(c)

        def f(c, xs):
            return jax.lax.scan(body, c, xs)

        text = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                        jax.ShapeDtypeStruct((9, 32, 32), jnp.float32))
        res = loop_aware_analysis(text)
        assert res["flops"] == 2 * 32 * 32 * 32 * 9

    def test_nested_scan_flops_exact(self):
        def inner(c, x):
            return c @ x, None

        def f(c, xs):
            def ob(c, _):
                c2, _ = jax.lax.scan(inner, c, xs)
                return c2, None
            return jax.lax.scan(ob, c, None, length=5)[0]

        text = _compile(f, jax.ShapeDtypeStruct((16, 16), jnp.float32),
                        jax.ShapeDtypeStruct((3, 16, 16), jnp.float32))
        res = loop_aware_analysis(text)
        assert res["flops"] == 2 * 16 ** 3 * 3 * 5

    def test_no_loop_matches_plain(self):
        def f(a, b):
            return a @ b

        text = _compile(f, jax.ShapeDtypeStruct((8, 64), jnp.float32),
                        jax.ShapeDtypeStruct((64, 8), jnp.float32))
        res = loop_aware_analysis(text)
        assert res["flops"] == 2 * 8 * 64 * 8

    def test_bytes_scale_with_trip_count(self):
        def f(c, xs):
            def body(c, x):
                return c + x * 2.0, None
            return jax.lax.scan(body, c, xs)[0]

        t3 = _compile(f, jax.ShapeDtypeStruct((1024,), jnp.float32),
                      jax.ShapeDtypeStruct((3, 1024), jnp.float32))
        t30 = _compile(f, jax.ShapeDtypeStruct((1024,), jnp.float32),
                       jax.ShapeDtypeStruct((30, 1024), jnp.float32))
        b3 = loop_aware_analysis(t3)["bytes_proxy"]
        b30 = loop_aware_analysis(t30)["bytes_proxy"]
        assert 5 < b30 / b3 < 15   # ~10x more loop traffic

    def test_dynamic_slice_counts_slice_not_operand(self):
        # scanning over a big stacked array must charge the slice, not
        # the whole stack, per iteration
        def f(xs):
            def body(c, i):
                return c + jax.lax.dynamic_index_in_dim(
                    xs, i, keepdims=False).sum(), None
            return jax.lax.scan(body, 0.0, jnp.arange(8))[0]

        text = _compile(f, jax.ShapeDtypeStruct((8, 4096), jnp.float32))
        res = loop_aware_analysis(text)
        total = 8 * 4096 * 4
        # full-stack-per-iteration would be >= 8x total (1.05 MB); the
        # slice-correct accounting lands ~5x (entry copies + slice reads
        # + reduction intermediates)
        assert total < res["bytes_proxy"] < 6.5 * total


class TestGroupStride:
    @pytest.mark.parametrize("line,expect", [
        ("%a = f32[4]{0} all-reduce(%x), replica_groups={{0,16,32,48}}, "
         "to_apply=%add", (4, 16)),
        ("%a = f32[4]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}",
         (2, 1)),
    ])
    def test_explicit_groups(self, line, expect):
        assert collective_group_stride(line) == expect

    def test_iota_groups(self):
        line = ("%a = f32[4] all-to-all(%x), "
                "replica_groups=[4,4]<=[16]")
        assert collective_group_stride(line) == (4, 1)
